"""Tests for the scale tier: the 3-tier substrate/job-mix generators, the
vectorized DES fast path (byte-identity under permuted tie-breaks), the
fluid executor's accuracy contract vs the DES, its refusal surface, and
the load-hotspot reporting that rides along."""
import dataclasses
import itertools
import json

import numpy as np
import pytest

from repro.analysis.audit import patch_tiebreak
from repro.core.fluid import FluidSim
from repro.core.plan import uniform_plan
from repro.core.platform import FailureEvent, planetlab_platform
from repro.core.simulate import SimConfig, open_schedule, simulate_schedule
from repro.core.topology import scale_job_mix, scale_tier_substrate

#: fluid-mode accuracy contract (documented in README / fluid.py): schedule
#: makespan relative error vs the chunk-granular DES.
FLUID_REL_TOL = 0.02


def _small_tier(seed=7):
    return scale_tier_substrate(
        n_regions=2, edges_per_region=6, mappers_per_region=4,
        n_backbone=1, reducers_per_backbone=4, seed=seed,
    )


def _result_key(res):
    """Canonical byte-comparison form of a schedule result."""
    return json.dumps(res.as_dict(), sort_keys=True)


class TestGenerators:
    def test_substrate_deterministic_by_seed(self):
        a, b = _small_tier(seed=7), _small_tier(seed=7)
        for field in ("B_sm", "B_mr", "C_m", "C_r"):
            np.testing.assert_array_equal(getattr(a, field),
                                          getattr(b, field))
        c = _small_tier(seed=8)
        assert not np.array_equal(a.B_sm, c.B_sm)

    def test_job_mix_deterministic_by_seed(self):
        sub = _small_tier()
        mix = lambda s: scale_job_mix(sub, n_jobs=5, seed=s,
                                      arrival_spread_s=50.0)
        for (pa, xa, ca), (pb, xb, cb) in zip(mix(3), mix(3)):
            np.testing.assert_array_equal(pa.D, pb.D)
            np.testing.assert_array_equal(xa.x, xb.x)
            np.testing.assert_array_equal(xa.y, xb.y)
            assert ca == cb
        other = mix(4)
        assert any(
            not np.array_equal(a[0].D, b[0].D)
            for a, b in zip(mix(3), other)
        )

    def test_job_mix_respects_base_cfg(self):
        sub = _small_tier()
        entries = scale_job_mix(
            sub, n_jobs=3, seed=0, base_cfg=SimConfig(mode="fluid")
        )
        assert all(cfg.mode == "fluid" for _, _, cfg in entries)


class TestVectorizedIdentity:
    """The vectorized DES must be byte-identical to the scalar event loop —
    including under permuted same-timestamp tie-breaks, which certifies
    the scenario (and hence the identity) as race-free."""

    @pytest.fixture(scope="class")
    def entries(self):
        sub = _small_tier()
        return sub, scale_job_mix(
            sub, n_jobs=6, seed=11, arrival_spread_s=40.0,
            base_cfg=SimConfig(chunk_mb=32.0, audit=True),
        )

    def _run(self, sub, entries, mode, rng=None):
        jobs = [(p, pl, dataclasses.replace(c, mode=mode))
                for p, pl, c in entries]
        eng = open_schedule(jobs, substrate=sub)
        if rng is not None:
            patch_tiebreak(eng, rng)
        return eng.run()

    def test_byte_identical_under_permuted_tiebreaks(self, entries):
        sub, jobs = entries
        vec = self._run(sub, jobs, mode="event_vec")
        assert vec.violations == []
        ref = _result_key(self._run(sub, jobs, mode="event"))
        assert _result_key(vec) == ref
        for seed in range(5):
            permuted = self._run(
                sub, jobs, mode="event",
                rng=np.random.default_rng(seed),
            )
            assert _result_key(permuted) == ref, f"tie-break seed {seed}"


class TestFluidAccuracy:
    """SimConfig(mode="fluid") reproduces the DES schedule makespan to
    within the documented tolerance, with the conservation auditor green
    on both sides."""

    @pytest.fixture(scope="class")
    def platform(self):
        return planetlab_platform(4, alpha=1.3, seed=5)

    @pytest.mark.parametrize(
        "barriers",
        ["".join(t) for t in itertools.product("GLP", repeat=3)],
    )
    def test_single_job_all_27_triples(self, platform, barriers):
        plan = uniform_plan(platform)
        des = simulate_schedule([(platform, plan, SimConfig(
            barriers=barriers, chunk_mb=4.0, mode="event_vec", audit=True))])
        fluid = simulate_schedule([(platform, plan, SimConfig(
            barriers=barriers, mode="fluid", audit=True))])
        assert des.violations == [] and fluid.violations == []
        rel = abs(fluid.makespan - des.makespan) / des.makespan
        assert rel <= FLUID_REL_TOL, f"{barriers}: rel error {rel:.4f}"

    @pytest.mark.parametrize("barriers", ["GGL", "PPP", "LLP"])
    def test_contended_two_job_schedule(self, platform, barriers):
        """Two jobs contending for the same links with staggered releases:
        the *schedule* makespan contract holds (per-job times of the
        shadowed job are not part of the fluid contract)."""
        plan = uniform_plan(platform)
        cfg_e = SimConfig(barriers=barriers, chunk_mb=4.0,
                          mode="event_vec", audit=True)
        des = simulate_schedule([
            (platform, plan, cfg_e),
            (platform, plan, dataclasses.replace(cfg_e, start_time=30.0,
                                                 chunk_mb=3.0)),
        ])
        cfg_f = SimConfig(barriers=barriers, mode="fluid", audit=True)
        fluid = simulate_schedule([
            (platform, plan, cfg_f),
            (platform, plan, dataclasses.replace(cfg_f, start_time=30.0)),
        ])
        assert des.violations == [] and fluid.violations == []
        rel = abs(fluid.makespan - des.makespan) / des.makespan
        assert rel <= FLUID_REL_TOL

    def test_scale_mix_fluid_runs(self):
        """The generated mix drains in fluid mode, deterministically."""
        sub = _small_tier()
        entries = scale_job_mix(sub, n_jobs=8, seed=2,
                                arrival_spread_s=60.0,
                                base_cfg=SimConfig(mode="fluid", audit=True))
        a = simulate_schedule(entries, substrate=sub)
        b = simulate_schedule(entries, substrate=sub)
        assert a.violations == []
        assert a.makespan == b.makespan
        assert _result_key(a) == _result_key(b)


class TestFluidRefusals:
    """Fluid mode refuses chunk-granular semantics loudly instead of
    silently approximating them."""

    @pytest.fixture(scope="class")
    def job(self):
        p = planetlab_platform(2, alpha=1.0, seed=0)
        return p, uniform_plan(p)

    def test_mixed_modes_rejected(self, job):
        p, plan = job
        with pytest.raises(ValueError, match="agree on SimConfig.mode"):
            open_schedule([
                (p, plan, SimConfig(mode="fluid")),
                (p, plan, SimConfig(mode="event")),
            ])

    def test_stage_links_rejected(self, job):
        p, plan = job
        with pytest.raises(ValueError, match="stage links"):
            open_schedule(
                [(p, plan, SimConfig(mode="fluid")),
                 (p, plan, SimConfig(mode="fluid"))],
                stage_links={1: [(0, 1.0)]},
            )

    @pytest.mark.parametrize("kwargs,match", [
        (dict(speculation=True), "speculation"),
        (dict(stealing=True), "stealing"),
        (dict(failures=[FailureEvent.mapper_kill(0, 10.0)]), "failures"),
        (dict(compute_noise=0.3), "compute_noise"),
        (dict(replication=2), "replication"),
    ])
    def test_dynamics_rejected(self, job, kwargs, match):
        p, plan = job
        with pytest.raises(ValueError, match=match):
            open_schedule([(p, plan, SimConfig(mode="fluid", **kwargs))])

    def test_event_cfg_rejected_on_inject(self, job):
        p, plan = job
        eng = open_schedule([(p, plan, SimConfig(mode="fluid"))])
        assert isinstance(eng, FluidSim)
        with pytest.raises(ValueError, match='mode="fluid"'):
            eng.inject([(p, plan, SimConfig(mode="event"))])


class TestFluidSteering:
    """The fluid engine exposes the same steering surface as the DES."""

    def test_run_until_snapshot_inject(self):
        sub = _small_tier()
        entries = scale_job_mix(sub, n_jobs=4, seed=5,
                                base_cfg=SimConfig(mode="fluid"))
        eng = open_schedule(entries, substrate=sub)
        eng.run_until(20.0)
        snap = eng.snapshot()
        assert snap.time == pytest.approx(20.0)
        assert any(jp.remaining_mb()["reduce"] > 0 for jp in snap.jobs)
        late = scale_job_mix(sub, n_jobs=1, seed=9,
                             base_cfg=SimConfig(mode="fluid",
                                                start_time=25.0))
        eng.inject(late)
        res = eng.run()
        assert eng.finished
        assert len(res.jobs) == 5
        # steered drain agrees with the unsteered one on the original jobs
        plain = simulate_schedule(entries + late, substrate=sub)
        assert res.makespan == pytest.approx(plain.makespan, rel=1e-9)

    def test_swap_plan_conserves(self):
        sub = _small_tier()
        entries = scale_job_mix(sub, n_jobs=2, seed=1,
                                base_cfg=SimConfig(mode="fluid", audit=True))
        eng = open_schedule(entries, substrate=sub)
        eng.run_until(15.0)
        p0, plan0, _ = entries[0]
        eng.swap_plan(0, uniform_plan(p0))
        res = eng.run()
        assert res.violations == []
        assert res.makespan > 0


class TestHotspots:
    """ResourceStats load warnings surface through ScheduleSimResult
    .hotspots() in both executor modes."""

    def test_thresholds_and_accessor(self):
        sub = _small_tier()
        entries = scale_job_mix(sub, n_jobs=4, seed=5,
                                base_cfg=SimConfig(mode="fluid"))
        res = simulate_schedule(entries, substrate=sub)
        # impossible thresholds -> clean; trivial thresholds -> every
        # served resource flagged with a readable reason
        assert res.hotspots(utilization_above=2.0,
                            backlog_age_above_s=1e12) == {}
        hot = res.hotspots(utilization_above=0.0, backlog_age_above_s=0.0)
        assert set(hot) <= set(res.resources)
        assert all(
            any("utilization" in w or "queue delay" in w for w in warns)
            for warns in hot.values()
        )
        name, stats = next(iter(res.resources.items()))
        assert stats.mean_wait_s >= 0.0
        assert stats.as_dict()["mean_wait_s"] == stats.mean_wait_s
