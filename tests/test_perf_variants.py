"""Correctness tests for the §Perf optimization levers: int8 KV cache and
pure-TP inference sharding must preserve semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import model as M
from repro.models.sharding import INFERENCE_RULES, DEFAULT_RULES


@pytest.fixture(scope="module")
def setup():
    cfg = ARCHS["musicgen-large"].reduced()
    # use token-in for this test: frontend stub replaced by tokens
    cfg = dataclasses.replace(cfg, frontend=None)
    params = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


class TestInt8KVCache:
    def test_decode_matches_fp_cache(self, setup):
        cfg, params = setup
        B, T = 2, 24
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, T + 4), 0, cfg.vocab)
        _, cache_fp, _ = M.prefill(cfg, params, {"tokens": toks[:, :T]},
                                   max_cache_len=T + 8)
        # build an int8 cache by replaying the prefill through decode steps
        cache_q = M.init_cache(cfg, B, T + 8, kv_int8=True)
        logits_q = None
        for t in range(T):
            batch = {"tokens": toks[:, t:t + 1],
                     "positions": jnp.full((B, 1), t, jnp.int32)}
            logits_q, cache_q, _ = M.decode_step(cfg, params, batch, cache_q)
        # now decode one more token from both caches
        batch = {"tokens": toks[:, T:T + 1],
                 "positions": jnp.full((B, 1), T, jnp.int32)}
        logits_fp, _, _ = M.decode_step(cfg, params, batch, cache_fp)
        logits_q2, _, _ = M.decode_step(cfg, params, batch, cache_q)
        # int8 quantization error is small but nonzero
        np.testing.assert_allclose(
            np.asarray(logits_q2), np.asarray(logits_fp), atol=0.15, rtol=0.1
        )
        # and the argmax (greedy token) agrees
        assert (
            np.argmax(np.asarray(logits_q2[:, -1]), -1)
            == np.argmax(np.asarray(logits_fp[:, -1]), -1)
        ).all()

    def test_int8_cache_is_half_the_bytes(self, setup):
        cfg, _ = setup
        fp = M.init_cache(cfg, 4, 64)
        q = M.init_cache(cfg, 4, 64, kv_int8=True)

        def nbytes(tree):
            return sum(
                np.prod(l.shape) * l.dtype.itemsize
                for l in jax.tree_util.tree_leaves(tree)
            )

        # int8 + per-position f32 scale ≈ (1 + 4/Dh) bytes vs 2 bytes
        assert nbytes(q) < 0.65 * nbytes(fp)


class TestInferenceRules:
    def test_fsdp_axes_dropped(self):
        assert INFERENCE_RULES["qkv_fsdp"] is None
        assert INFERENCE_RULES["ffn_fsdp"] is None
        assert DEFAULT_RULES["qkv_fsdp"] == "data"
        # activations/TP axes unchanged
        assert INFERENCE_RULES["heads"] == DEFAULT_RULES["heads"]
        assert INFERENCE_RULES["act_batch"] == DEFAULT_RULES["act_batch"]
