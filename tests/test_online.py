"""Tests for the online control plane (PR 3): capacity traces, the
observable/steerable executor (snapshot / plan swap / streaming job
injection), residual pricing on the shared cost model, warm-started
re-planning, online policies, the fairness schedule objective, and the
staggered-release semantics they all build on."""
import dataclasses
import itertools

import numpy as np
import pytest

from repro.api import Arrival, GeoJob, GeoSchedule, OnlineReport
from repro.core.makespan import (
    BARRIERS_GGL,
    CostModel,
    JobProgress,
    makespan,
)
from repro.core.optimize import (
    available_online_policies,
    get_online_policy,
    optimize_schedule,
    register_online_policy,
    replan,
)
from repro.core.plan import ExecutionPlan, uniform_plan
from repro.core.platform import CapacityTrace, FailureEvent, \
    Substrate, planetlab_platform
from repro.core.simulate import (
    SimConfig,
    open_schedule,
    simulate,
    simulate_schedule,
)

ALL_BARRIER_TRIPLES = list(itertools.product("GLP", repeat=3))

OPT = dict(n_restarts=6, steps=150)


def pair_substrate(**traces) -> Substrate:
    """2 sources / 2 mappers / 2 reducers, every capacity distinct enough
    to exercise routing, optionally with capacity traces attached."""
    sub = Substrate(
        B_sm=np.array([[200.0, 150.0], [150.0, 200.0]]),
        B_mr=np.array([[500.0, 100.0], [500.0, 100.0]]),
        C_m=np.array([100.0, 100.0]),
        C_r=np.array([2000.0, 2000.0]),
        cluster_s=np.array([0, 1]),
        cluster_m=np.array([0, 1]),
        cluster_r=np.array([0, 1]),
        name="online_pair",
    )
    return sub.with_traces(traces) if traces else sub


def online_drift_substrate(t_drift: float = 105.0) -> Substrate:
    """The schedule_online scenario fabric: both backbone links into the
    fast-path reducer r0 degrade 250x at ``t_drift`` (mid-shuffle of the
    steady job)."""
    return pair_substrate(**{
        "shuffle[m0->r0]": CapacityTrace.step(500.0, 2.0, t_drift),
        "shuffle[m1->r0]": CapacityTrace.step(500.0, 2.0, t_drift),
    })


# ---------------------------------------------------------------------------
# capacity traces and the drifting substrate
# ---------------------------------------------------------------------------


class TestCapacityTrace:
    def test_step_function_semantics(self):
        tr = CapacityTrace(times=(0.0, 10.0, 20.0), values=(5.0, 1.0, 3.0))
        assert tr.at(0.0) == 5.0
        assert tr.at(9.999) == 5.0
        assert tr.at(10.0) == 1.0  # right-open: the new value holds at t
        assert tr.at(19.0) == 1.0
        assert tr.at(1e9) == 3.0

    def test_step_constructor(self):
        tr = CapacityTrace.step(100.0, 2.0, 7.5)
        assert tr.at(7.4) == 100.0 and tr.at(7.5) == 2.0

    def test_validation(self):
        with pytest.raises(ValueError, match="start at t=0"):
            CapacityTrace(times=(1.0,), values=(5.0,))
        with pytest.raises(ValueError, match="strictly increase"):
            CapacityTrace(times=(0.0, 5.0, 5.0), values=(1.0, 2.0, 3.0))
        with pytest.raises(ValueError, match="strictly positive"):
            CapacityTrace(times=(0.0, 1.0), values=(1.0, 0.0))
        with pytest.raises(ValueError, match="equal-length"):
            CapacityTrace(times=(0.0,), values=(1.0, 2.0))

    def test_substrate_trace_keys_validated(self):
        sub = pair_substrate()
        with pytest.raises(ValueError, match="unknown trace key"):
            sub.with_traces({"nonsense": CapacityTrace.step(1.0, 2.0, 1.0)})
        with pytest.raises(ValueError, match="unknown trace key"):
            # out of range for a 2x2 substrate
            sub.with_traces({"map[m7]": CapacityTrace.step(1.0, 2.0, 1.0)})

    def test_substrate_at_folds_traces(self):
        sub = online_drift_substrate(t_drift=50.0)
        before, after = sub.at(49.0), sub.at(50.0)
        assert before.B_mr[0, 0] == 500.0 and before.B_mr[1, 0] == 500.0
        assert after.B_mr[0, 0] == 2.0 and after.B_mr[1, 0] == 2.0
        # untraced entries unchanged; result is a plain substrate
        assert after.B_mr[0, 1] == 100.0
        assert after.traces is None
        assert sub.drift_times() == (50.0,)

    def test_residual_drops_traces(self):
        sub = online_drift_substrate()
        assert sub.residual(map_frac=np.array([0.5, 0.0])).traces is None

    def test_executor_applies_drift_to_queued_chunks(self):
        """A transfer that starts after the step serves at the new rate;
        already-started service keeps its rate."""
        sub = pair_substrate(**{
            "push[s0->m0]": CapacityTrace.step(200.0, 1.0, 5.0)
        })
        v = sub.view(np.array([2000.0, 0.0]), 1.0)
        plan = ExecutionPlan(x=np.array([[1.0, 0.0], [0.5, 0.5]]),
                             y=np.array([0.5, 0.5]))
        cfg = SimConfig(barriers=BARRIERS_GGL, chunk_mb=100.0)
        nominal = pair_substrate().view(np.array([2000.0, 0.0]), 1.0)
        base = simulate(nominal, plan, cfg).makespan
        drifted = simulate(v, plan, cfg).makespan
        assert drifted > base * 5  # ~1500 MB queued at 1 MB/s


# ---------------------------------------------------------------------------
# SimConfig validation (negative values used to flow into the event loop)
# ---------------------------------------------------------------------------


class TestSimConfigValidation:
    def test_negative_start_time_rejected(self):
        with pytest.raises(ValueError, match="start_time"):
            SimConfig(start_time=-1.0)

    def test_zero_replication_rejected(self):
        with pytest.raises(ValueError, match="replication"):
            SimConfig(replication=0)
        with pytest.raises(ValueError, match="replication"):
            SimConfig(replication=-2)

    def test_valid_boundaries_accepted(self):
        assert SimConfig(start_time=0.0, replication=1).replication == 1


# ---------------------------------------------------------------------------
# the acceptance bar: static online == the frozen offline pipeline
# ---------------------------------------------------------------------------


class TestStaticEquivalence:
    @pytest.mark.parametrize("barriers", ALL_BARRIER_TRIPLES,
                             ids=["".join(b) for b in ALL_BARRIER_TRIPLES])
    def test_static_reproduces_offline_pipeline(self, barriers):
        """`static` run_online == simulate_schedule phase-for-phase (1e-9)
        on every barrier triple, with a streaming arrival and capacity
        drift in play — the control loop without control is exactly the
        offline pipeline."""
        sub = online_drift_substrate(t_drift=40.0)
        v1 = sub.view(np.array([3000.0, 3000.0]), 1.0, name="steady")
        v2 = sub.view(np.array([1500.0, 1500.0]), 1.0, name="late")
        plan1, plan2 = uniform_plan(v1), uniform_plan(v2)
        cfg = SimConfig(barriers=barriers, chunk_mb=256.0)
        t_arrival = 13.7

        sched = GeoSchedule([GeoJob(v1).with_plan(plan1, barriers)]).with_plans()
        report = sched.run_online(
            policy="static",
            arrivals=[Arrival(GeoJob(v2).with_plan(plan2, barriers),
                              t_arrival)],
            cfg=cfg,
        )
        ref = simulate_schedule(
            [(v1, plan1, cfg),
             (v2, plan2, dataclasses.replace(cfg, start_time=t_arrival))],
            substrate=sub,
        )
        assert len(report.sim.jobs) == len(ref.jobs) == 2
        for got, want in zip(report.sim.jobs, ref.jobs):
            for phase, t in want.phases().items():
                assert abs(got.phases()[phase] - t) <= 1e-9, phase
        assert abs(report.makespan_online - ref.makespan) <= 1e-9
        # same plans: nothing was swapped, the objects themselves ran
        assert report.swaps == ()
        assert report.plans[0] is plan1 and report.plans[1] is plan2
        # and the report's own static baseline is the run itself
        assert report.makespan_online == report.makespan_static


# ---------------------------------------------------------------------------
# snapshots and residual pricing
# ---------------------------------------------------------------------------


class TestSnapshot:
    def setup_engine(self, barriers=BARRIERS_GGL, start_time=0.0):
        sub = pair_substrate()
        v = sub.view(np.array([2000.0, 1000.0]), 1.5, name="observed")
        plan = uniform_plan(v)
        cfg = SimConfig(barriers=barriers, chunk_mb=100.0,
                        start_time=start_time)
        return sub, v, plan, open_schedule([(v, plan, cfg)], substrate=sub)

    def test_unreleased_job_is_fresh(self):
        sub, v, plan, eng = self.setup_engine(start_time=100.0)
        eng.run_until(1.0)
        jp = eng.snapshot().jobs[0]
        assert not jp.released and not jp.done
        np.testing.assert_allclose(jp.resid_push, v.D)
        assert jp.remaining_mb()["push"] == pytest.approx(3000.0)
        assert jp.completion()["push"] == pytest.approx(0.0)

    def test_volume_conservation_over_time(self):
        """At every observation instant the residual map-input volume never
        exceeds the total and only shrinks as the run progresses."""
        sub, v, plan, eng = self.setup_engine()
        total = float(v.D.sum())
        horizon = simulate(v, plan,
                           SimConfig(barriers=BARRIERS_GGL,
                                     chunk_mb=100.0)).makespan
        prev = np.inf
        for frac in (0.1, 0.3, 0.5, 0.8, 1.1):
            eng.run_until(horizon * frac)
            jp = eng.snapshot().jobs[0]
            rem = jp.remaining_mb()
            assert rem["map"] <= total + 1e-6
            assert rem["map"] <= prev + 1e-6
            prev = rem["map"]
            comp = jp.completion()
            assert all(0.0 <= c <= 1.0 for c in comp.values())
        assert jp.done and rem["reduce"] == pytest.approx(0.0)

    def test_fresh_residual_prices_like_plan(self):
        """The zero-progress snapshot priced through price_residual equals
        price_plan bit-for-bit on every barrier triple — online and offline
        share one cost model."""
        p = planetlab_platform(4, alpha=1.3, seed=2)
        plan = uniform_plan(p)
        fresh = JobProgress.fresh(p)
        for barriers in ALL_BARRIER_TRIPLES:
            cm = CostModel(p, barriers)
            assert cm.residual_makespan(fresh, plan) == pytest.approx(
                cm.makespan(plan), abs=1e-9
            )

    def test_residual_shrinks_with_progress(self):
        sub, v, plan, eng = self.setup_engine()
        cm = CostModel(v, BARRIERS_GGL)
        full = cm.residual_makespan(JobProgress.fresh(v), plan)
        horizon = simulate(v, plan,
                           SimConfig(barriers=BARRIERS_GGL,
                                     chunk_mb=100.0)).makespan
        eng.run_until(horizon * 0.6)
        mid = cm.residual_makespan(eng.snapshot().jobs[0], plan)
        assert 0.0 < mid < full

    def test_backlog_accounting(self):
        sub, v, plan, eng = self.setup_engine()
        eng.run_until(0.5)
        snap = eng.snapshot()
        assert set(snap.backlog) == set(sub.resources())
        assert sum(snap.backlog.values()) > 0
        assert snap.time == 0.5


# ---------------------------------------------------------------------------
# steering: plan swap and streaming injection
# ---------------------------------------------------------------------------


class TestSwapAndInject:
    @pytest.mark.parametrize("barriers", [("G", "G", "L"), ("G", "L", "L"),
                                          ("P", "P", "P"), ("L", "G", "G")],
                             ids=lambda b: "".join(b))
    def test_identity_swap_preserves_completion(self, barriers):
        """Swapping a plan for itself mid-run re-routes nothing of
        substance: the job still completes and every alpha-expanded byte
        still reaches the reducers."""
        sub = pair_substrate()
        v = sub.view(np.array([2000.0, 1000.0]), 1.0)
        plan = uniform_plan(v)
        cfg = SimConfig(barriers=barriers, chunk_mb=100.0)
        ref = simulate(v, plan, cfg)
        eng = open_schedule([(v, plan, cfg)], substrate=sub)
        eng.run_until(ref.makespan * 0.4)
        eng.swap_plan(0, ExecutionPlan(x=plan.x.copy(), y=plan.y.copy(),
                                       meta="identity"))
        res = eng.run()
        sim = res.jobs[0]
        assert np.isfinite(sim.makespan) and sim.makespan > 0
        reduced = sum(s.volume_mb for n, s in res.resources.items()
                      if n.startswith("reduce["))
        assert reduced == pytest.approx(3000.0)

    def test_swap_reroutes_around_degraded_link(self):
        """The point of the whole machinery: when a link collapses under a
        frozen plan, swapping a plan that routes around it recovers most of
        the loss."""
        sub = pair_substrate(**{
            "push[s0->m0]": CapacityTrace.step(200.0, 1.0, 5.0)
        })
        v = sub.view(np.array([4000.0, 0.0]), 1.0)
        pinned = ExecutionPlan(x=np.array([[1.0, 0.0], [0.5, 0.5]]),
                               y=np.array([0.5, 0.5]))
        rerouted = ExecutionPlan(x=np.array([[0.0, 1.0], [0.5, 0.5]]),
                                 y=np.array([0.5, 0.5]))
        cfg = SimConfig(barriers=BARRIERS_GGL, chunk_mb=100.0)
        frozen = simulate(v, pinned, cfg).makespan
        eng = open_schedule([(v, pinned, cfg)], substrate=sub)
        eng.run_until(5.0)
        eng.swap_plan(0, rerouted)
        online = eng.run().jobs[0].makespan
        assert online < frozen * 0.2

    def test_swap_before_release_replaces_plan_wholesale(self):
        sub = pair_substrate()
        v = sub.view(np.array([1000.0, 1000.0]), 1.0)
        cfg = SimConfig(barriers=BARRIERS_GGL, start_time=50.0)
        better = ExecutionPlan(x=np.array([[1.0, 0.0], [0.0, 1.0]]),
                               y=np.array([0.5, 0.5]))
        eng = open_schedule([(v, uniform_plan(v), cfg)], substrate=sub)
        eng.run_until(10.0)
        eng.swap_plan(0, better)
        res = eng.run()
        ref = simulate(v, better, cfg)
        assert res.jobs[0].phases() == ref.phases()

    def test_swap_shape_mismatch_raises(self):
        sub = pair_substrate()
        v = sub.view(np.array([1000.0, 1000.0]), 1.0)
        eng = open_schedule([(v, uniform_plan(v))], substrate=sub)
        with pytest.raises(ValueError, match="do not match"):
            eng.swap_plan(0, ExecutionPlan(x=np.ones((3, 3)) / 3,
                                           y=np.ones(3) / 3))

    def test_inject_matches_offline_release(self):
        """Mid-run injection is event-identical to an offline start_time
        release (the streaming-arrival acceptance invariant)."""
        sub = pair_substrate()
        a = sub.view(np.array([2000.0, 1000.0]), 1.0, name="a")
        b = sub.view(np.array([500.0, 500.0]), 1.0, name="b")
        cfg = SimConfig(barriers=BARRIERS_GGL, chunk_mb=100.0)
        late = dataclasses.replace(cfg, start_time=7.3)
        ref = simulate_schedule([(a, uniform_plan(a), cfg),
                                 (b, uniform_plan(b), late)], substrate=sub)
        eng = open_schedule([(a, uniform_plan(a), cfg)], substrate=sub)
        eng.run_until(7.3)
        eng.inject([(b, uniform_plan(b), late)])
        got = eng.run()
        for x, y in zip(got.jobs, ref.jobs):
            assert x.phases() == y.phases()

    def test_inject_at_pending_release_merges_seed_group(self):
        """An injection landing exactly on another job's release time joins
        its round-robin seed group, matching the offline grouping (shared
        links must interleave the jobs' chunks, not serve the newcomer
        first)."""
        sub = pair_substrate()
        a = sub.view(np.array([2000.0, 1000.0]), 1.0, name="held")
        b = sub.view(np.array([1500.0, 500.0]), 1.0, name="joiner")
        t0 = 25.0
        cfg = SimConfig(barriers=BARRIERS_GGL, chunk_mb=50.0, start_time=t0)
        ref = simulate_schedule([(a, uniform_plan(a), cfg),
                                 (b, uniform_plan(b), cfg)], substrate=sub)
        eng = open_schedule([(a, uniform_plan(a), cfg)], substrate=sub)
        eng.run_until(t0)
        eng.inject([(b, uniform_plan(b), cfg)])
        got = eng.run()
        for x, y in zip(got.jobs, ref.jobs):
            assert x.phases() == y.phases()

    def test_swap_never_routes_pulled_chunks_to_dead_mapper(self):
        """The largest-deficit assignment stays inside the eligible set:
        even when the new plan keeps weight on a dead mapper, pulled chunks
        go to survivors (no pointless push->recover round trips)."""
        sub = pair_substrate()
        v = sub.view(np.array([4000.0, 2000.0]), 1.0)
        cfg = SimConfig(barriers=BARRIERS_GGL, chunk_mb=50.0,
                        failures=[FailureEvent.mapper_kill(1, 3.0)])
        eng = open_schedule([(v, uniform_plan(v), cfg)], substrate=sub)
        eng.run_until(3.0, inclusive=True)  # the worker is dead now
        recovered_at_fail = eng.runs[0].recovered
        # committed = transfers already in service toward the dead mapper
        in_service = sum(
            1 for row in eng.push_links for link in row
            if link.current is not None
            and link.current.fn == "push_arrive"
            and link.current.args[2] == 1
        )
        # new plan still puts 70% on the dead mapper — the swap must ignore it
        eng.swap_plan(0, ExecutionPlan(x=np.array([[0.3, 0.7], [0.3, 0.7]]),
                                       y=np.array([0.5, 0.5])))
        # nothing re-routed by the swap is queued toward the dead mapper
        for i, row in enumerate(eng.push_links):
            assert not any(tr.fn == "push_arrive" for tr in row[1].queue)
        res = eng.run()
        # only the chunks already committed at fail time needed recovery
        assert res.jobs[0].recovered_chunks == recovered_at_fail + in_service
        assert np.isfinite(res.jobs[0].makespan)

    def test_replan_routes_around_dead_mapper(self):
        """JobProgress carries worker liveness and replan() degrades dead
        mappers' capacity, so the adopted plan moves x mass to survivors."""
        sub = pair_substrate()
        v = sub.view(np.array([4000.0, 2000.0]), 1.0)
        cfg = SimConfig(barriers=BARRIERS_GGL, chunk_mb=50.0,
                        failures=[FailureEvent.mapper_kill(0, 5.0)])
        eng = open_schedule([(v, uniform_plan(v), cfg)], substrate=sub)
        eng.run_until(5.0, inclusive=True)
        jp = eng.snapshot().jobs[0]
        assert jp.map_alive is not None and not jp.map_alive[0]
        res = replan(sub.view(v.D, v.alpha), uniform_plan(v), progress=jp,
                     barriers=BARRIERS_GGL, **OPT)
        assert res.plan is not None
        # the re-routable residual concentrates on the surviving mapper
        assert res.plan.x[:, 1].mean() > 0.9

    def test_inject_mismatched_substrate_raises(self):
        sub = pair_substrate()
        v = sub.view(np.array([1000.0, 1000.0]), 1.0)
        eng = open_schedule([(v, uniform_plan(v))], substrate=sub)
        other = planetlab_platform(2, seed=0)
        with pytest.raises(ValueError, match="not a view"):
            eng.inject([(other, uniform_plan(other))])

    def test_open_schedule_empty_raises(self):
        with pytest.raises(ValueError, match="at least one job"):
            open_schedule([])


# ---------------------------------------------------------------------------
# warm-started re-planning
# ---------------------------------------------------------------------------


class TestReplan:
    def test_never_worse_than_incumbent(self):
        """The incumbent competes: replan returns the incumbent plan object
        itself when nothing beats it, and never a modeled-worse plan."""
        sub = pair_substrate()
        v = sub.view(np.array([2000.0, 1000.0]), 1.0)
        cm = CostModel(v, BARRIERS_GGL)
        # a strong incumbent on a static platform: hard to beat
        strong = GeoJob(v).plan("e2e_multi", barriers=BARRIERS_GGL,
                                **OPT).planned.plan
        res = replan(v, strong, barriers=BARRIERS_GGL, **OPT)
        assert res.makespan <= cm.makespan(strong) + 1e-9

    def test_improves_on_degraded_view(self):
        """Re-planning against the post-drift view routes the residual
        around the degraded links (warm-started from the incumbent)."""
        sub = online_drift_substrate(t_drift=5.0)
        v = sub.view(np.array([8000.0, 8000.0]), 1.0)
        # incumbent concentrates shuffle on r0 — optimal nominally, fatal
        # after the drift
        incumbent = ExecutionPlan(x=uniform_plan(v).x,
                                  y=np.array([1.0, 0.0]))
        cfg = SimConfig(barriers=BARRIERS_GGL, chunk_mb=100.0)
        eng = open_schedule([(v, incumbent, cfg)], substrate=sub)
        eng.run_until(60.0)  # past the drift, mid-run
        jp = eng.snapshot().jobs[0]
        view = sub.at(60.0).view(v.D, v.alpha)
        cm = CostModel(view, BARRIERS_GGL)
        before = cm.residual_makespan(jp, incumbent)
        res = replan(view, incumbent, progress=jp, barriers=BARRIERS_GGL,
                     **OPT)
        assert res.plan is not incumbent
        assert res.makespan < before * 0.5
        # the adopted y routes away from the degraded r0 links
        assert res.plan.y[0] < 0.5

    def test_result_is_residual_priced(self):
        sub = pair_substrate()
        v = sub.view(np.array([2000.0, 1000.0]), 1.0)
        plan = uniform_plan(v)
        res = replan(v, plan, progress=None, barriers=BARRIERS_GGL, **OPT)
        cm = CostModel(v, BARRIERS_GGL)
        assert res.makespan == pytest.approx(
            cm.residual_makespan(JobProgress.fresh(v), res.plan), abs=1e-9
        )
        assert res.mode == "replan"


# ---------------------------------------------------------------------------
# online policies and the closed loop
# ---------------------------------------------------------------------------


def _drift_jobs():
    sub = online_drift_substrate(t_drift=105.0)
    steady = GeoJob(sub.view(np.array([8000.0, 8000.0]), 1.0, name="steady"))
    late = GeoJob(sub.view(np.array([4000.0, 4000.0]), 1.0, name="late"))
    return sub, steady, late


class TestOnlinePolicies:
    def test_builtin_policies_registered(self):
        assert {"static", "reactive", "horizon"} <= set(
            available_online_policies()
        )

    def test_unknown_policy_raises(self):
        with pytest.raises(ValueError, match="online policy must be one of"):
            get_online_policy("no_such_policy")

    def test_duplicate_registration_raises(self):
        with pytest.raises(ValueError, match="already registered"):
            register_online_policy("static", lambda *a: False)

    def test_policy_semantics(self):
        static = get_online_policy("static")
        reactive = get_online_policy("reactive")
        horizon = get_online_policy("horizon")
        for kind in ("arrival", "drift", "failure", "tick"):
            assert static(kind, None) is False
        assert reactive("drift", None) and reactive("arrival", None)
        assert reactive("failure", None) and not reactive("tick", None)
        assert horizon("tick", None) and not horizon("drift", None)

    def test_reactive_beats_frozen_joint_by_15pct(self):
        """THE acceptance scenario: a backbone link degrades mid-shuffle and
        a second job arrives mid-map.  The frozen joint plan (clairvoyant
        about the arrival, blind to the drift) crawls; reactive re-planning
        recovers >= 15% of the aggregate makespan."""
        sub, steady, late = _drift_jobs()
        cfg = SimConfig(barriers=BARRIERS_GGL)
        t_arrival = 50.0

        # frozen joint: both jobs planned together offline (it even knows
        # the arrival's release time will be enforced) on nominal capacity
        frozen = GeoSchedule([steady, late]).plan(
            "joint", mode="e2e_multi", barriers=BARRIERS_GGL, **OPT
        )
        frozen_sim = simulate_schedule(
            [(steady.platform, frozen.planned.plans[0], cfg),
             (late.platform, frozen.planned.plans[1],
              dataclasses.replace(cfg, start_time=t_arrival))],
            substrate=sub,
        )

        # reactive: steady planned offline, late streams in at t=50
        online = GeoSchedule([steady]).plan(
            "independent", mode="e2e_multi", barriers=BARRIERS_GGL, **OPT
        ).run_online(
            policy="reactive",
            arrivals=[Arrival(GeoJob(late.platform).with_plan(
                frozen.planned.plans[1], BARRIERS_GGL), t_arrival)],
            cfg=cfg, **OPT,
        )
        assert isinstance(online, OnlineReport)
        # the drift fired a decision and at least one swap happened
        assert any(d.event == "drift" for d in online.decisions)
        assert len(online.swaps) >= 1
        gain = 1.0 - online.makespan_online / frozen_sim.makespan
        assert gain >= 0.15, (
            f"reactive {online.makespan_online:.0f}s vs frozen joint "
            f"{frozen_sim.makespan:.0f}s — only {gain:.0%}"
        )
        # and against its own matched frozen baseline too
        assert online.improvement >= 0.15

    def test_horizon_policy_recovers_via_ticks(self):
        sub, steady, late = _drift_jobs()
        cfg = SimConfig(barriers=BARRIERS_GGL)
        report = GeoSchedule([steady]).plan(
            "independent", mode="e2e_multi", barriers=BARRIERS_GGL, **OPT
        ).run_online(
            policy="horizon",
            arrivals=[Arrival(GeoJob(late.platform).with_plan(
                uniform_plan(late.platform), BARRIERS_GGL), 50.0)],
            cfg=cfg, replan_dt=40.0, **OPT,
        )
        assert any(d.event == "tick" for d in report.decisions)
        assert report.improvement >= 0.15

    def test_horizon_requires_replan_dt(self):
        sub, steady, late = _drift_jobs()
        sched = GeoSchedule([steady]).plan(
            "independent", mode="uniform", barriers=BARRIERS_GGL
        )
        with pytest.raises(ValueError, match="replan_dt"):
            sched.run_online(policy="horizon",
                             cfg=SimConfig(barriers=BARRIERS_GGL))
        with pytest.raises(ValueError, match="replan_dt must be > 0"):
            sched.run_online(policy="horizon", replan_dt=0.0,
                             cfg=SimConfig(barriers=BARRIERS_GGL))

    def test_reactive_failure_decision_sees_post_failure_state(self):
        """The failure decision fires AFTER the worker dies: the snapshot's
        residual already holds the recovered chunks in flight to surviving
        mappers, the replan/swap routes around the dead node, and the run
        completes no slower than the frozen recovery path."""
        sub = pair_substrate()
        v = sub.view(np.array([4000.0, 2000.0]), 1.0, name="doomed")
        cfg = SimConfig(barriers=BARRIERS_GGL, chunk_mb=100.0,
                        failures=[FailureEvent.mapper_kill(0, 10.0)])
        sched = GeoSchedule(
            [GeoJob(v).with_plan(uniform_plan(v), BARRIERS_GGL)]
        ).with_plans()
        report = sched.run_online(policy="reactive", cfg=cfg, **OPT)
        fails = [d for d in report.decisions if d.event == "failure"]
        assert len(fails) == 1 and fails[0].time == 10.0
        assert np.isfinite(report.makespan_online)
        # static baseline ran the same failure; online never does worse
        # than frozen by more than noise from re-chunked transfers
        assert report.makespan_online <= report.makespan_static * 1.05

    def test_custom_policy_plugs_in(self):
        from repro.core import optimize as O

        seen = []

        @register_online_policy("test_never")
        def _never(kind, snapshot):
            seen.append(kind)
            return False

        try:
            sub, steady, late = _drift_jobs()
            report = GeoSchedule([steady]).plan(
                "independent", mode="uniform", barriers=BARRIERS_GGL
            ).run_online(
                policy="test_never",
                arrivals=[Arrival(GeoJob(late.platform).with_plan(
                    uniform_plan(late.platform), BARRIERS_GGL), 50.0)],
                cfg=SimConfig(barriers=BARRIERS_GGL),
            )
            assert "drift" in seen and "arrival" in seen
            assert report.swaps == ()  # declined every decision
            assert report.makespan_online == report.makespan_static
        finally:
            del O._ONLINE_POLICIES["test_never"]

    def test_timeline_and_summary_render(self):
        sub, steady, late = _drift_jobs()
        report = GeoSchedule([steady]).plan(
            "independent", mode="uniform", barriers=BARRIERS_GGL
        ).run_online(policy="static", cfg=SimConfig(barriers=BARRIERS_GGL))
        assert "online[static]" in report.summary()
        assert report.timeline() == "(no decisions)"


# ---------------------------------------------------------------------------
# fairness objective (min-max slowdown)
# ---------------------------------------------------------------------------


def asymmetric_views():
    sub = Substrate(
        B_sm=np.array([[10_000.0, 1.0], [10_000.0, 10_000.0]]),
        B_mr=np.full((2, 2), 10_000.0),
        C_m=np.array([50.0, 50.0]),
        C_r=np.array([10_000.0, 10_000.0]),
        cluster_s=np.array([0, 1]),
        cluster_m=np.array([0, 1]),
        cluster_r=np.array([0, 1]),
        name="contended_pair",
    )
    return [sub.view(np.array([40_000.0, 0.0]), 1.0, name="pinned"),
            sub.view(np.array([0.0, 40_000.0]), 1.0, name="flexible")]


class TestFairnessObjective:
    def max_slowdown(self, views, result, barriers, opts):
        """Per-job contended makespan over its independent-plan sole-tenant
        makespan (the same references the joint solver uses)."""
        indep = optimize_schedule(views, policy="independent",
                                  barriers=barriers, **opts)
        refs = np.array([
            makespan(v, r.plan, barriers=barriers)
            for v, r in zip(views, indep.results)
        ])
        spans = np.array([r.makespan for r in result.results])
        return float(np.max(spans / np.maximum(refs, 1e-9)))

    def test_fairness_never_increases_max_slowdown(self):
        """The satellite acceptance: on the asymmetric-access scenario the
        fairness objective's max slowdown is no worse than joint's."""
        views = asymmetric_views()
        opts = dict(mode="e2e_multi", n_restarts=8, steps=250)
        joint = optimize_schedule(views, policy="joint",
                                  barriers=BARRIERS_GGL, **opts)
        fair = optimize_schedule(views, policy="joint",
                                 barriers=BARRIERS_GGL,
                                 objective="min_max_slowdown", **opts)
        sd_joint = self.max_slowdown(views, joint, BARRIERS_GGL, opts)
        sd_fair = self.max_slowdown(views, fair, BARRIERS_GGL, opts)
        assert sd_fair <= sd_joint + 1e-9
        assert fair.objective == "min_max_slowdown"
        assert joint.objective == "makespan"

    def test_unknown_objective_rejected(self):
        views = asymmetric_views()
        with pytest.raises(ValueError, match="objective must be one of"):
            optimize_schedule(views, policy="joint", objective="bogus")

    def test_objective_requires_policy_support(self):
        views = asymmetric_views()
        with pytest.raises(ValueError, match="does not take an objective"):
            optimize_schedule(views, policy="independent", mode="uniform",
                              objective="min_max_slowdown")


# ---------------------------------------------------------------------------
# staggered releases under contention (start_time + shared resources)
# ---------------------------------------------------------------------------


class TestStaggeredRelease:
    def test_no_capacity_consumed_before_release(self):
        """A job released at t>0 leaves every resource untouched before its
        release: first service timestamps respect the offset, and the
        absolute-horizon utilization stays consistent."""
        sub = pair_substrate()
        v = sub.view(np.array([2000.0, 1000.0]), 1.0)
        t0 = 200.0
        res = simulate_schedule(
            [(v, uniform_plan(v),
              SimConfig(barriers=BARRIERS_GGL, start_time=t0))],
            substrate=sub,
        )
        for name, stats in res.resources.items():
            if stats.n_chunks == 0:
                continue
            assert stats.first_busy_s >= t0, name
            assert stats.busy_s <= res.makespan - t0 + 1e-9, name
        util = res.utilization()
        assert all(0.0 <= u <= 1.0 + 1e-9 for u in util.values())

    def test_offset_shifts_solo_run_exactly(self):
        sub = pair_substrate()
        v = sub.view(np.array([2000.0, 1000.0]), 1.0)
        plan = uniform_plan(v)
        base = simulate(v, plan, SimConfig(barriers=BARRIERS_GGL))
        late = simulate(v, plan,
                        SimConfig(barriers=BARRIERS_GGL, start_time=123.0))
        assert late.makespan == pytest.approx(base.makespan + 123.0,
                                              rel=1e-12)
        for stats in simulate_schedule(
            [(v, plan, SimConfig(barriers=BARRIERS_GGL, start_time=123.0))],
            substrate=sub,
        ).resources.values():
            if stats.n_chunks:
                assert stats.last_busy_s <= base.makespan + 123.0 + 1e-9

    def test_staggered_contention_orders_service(self):
        """Two jobs staggered on shared links: the late job never consumes
        capacity before release, the early job is never delayed by work
        that has not been released yet."""
        sub = pair_substrate()
        a = sub.view(np.array([2000.0, 1000.0]), 1.0, name="early")
        b = sub.view(np.array([2000.0, 1000.0]), 1.0, name="late")
        plan_a, plan_b = uniform_plan(a), uniform_plan(b)
        solo_a = simulate(a, plan_a, SimConfig(barriers=BARRIERS_GGL))
        t0 = solo_a.makespan + 10.0  # release b after a has fully drained
        sched = simulate_schedule(
            [(a, plan_a, SimConfig(barriers=BARRIERS_GGL)),
             (b, plan_b, SimConfig(barriers=BARRIERS_GGL, start_time=t0))],
            substrate=sub,
        )
        # a sees zero contention; b runs exactly as if alone, offset by t0
        for phase, want in solo_a.phases().items():
            assert sched.jobs[0].phases()[phase] == pytest.approx(want)
        solo_b = simulate(b, plan_b, SimConfig(barriers=BARRIERS_GGL))
        assert sched.jobs[1].makespan == pytest.approx(
            solo_b.makespan + t0, rel=1e-12
        )
        # resources served both jobs, in order
        for name, stats in sched.resources.items():
            if stats.n_chunks:
                assert stats.first_busy_s < t0

    def test_overlapping_release_contends(self):
        sub = pair_substrate()
        a = sub.view(np.array([4000.0, 2000.0]), 1.0, name="early")
        b = sub.view(np.array([4000.0, 2000.0]), 1.0, name="overlap")
        plan_a, plan_b = uniform_plan(a), uniform_plan(b)
        solo_b = simulate(b, plan_b, SimConfig(barriers=BARRIERS_GGL))
        t0 = 5.0
        sched = simulate_schedule(
            [(a, plan_a, SimConfig(barriers=BARRIERS_GGL)),
             (b, plan_b, SimConfig(barriers=BARRIERS_GGL, start_time=t0))],
            substrate=sub,
        )
        assert len(sched.contended()) > 0
        assert sched.jobs[1].makespan >= solo_b.makespan + t0 - 1e-9


# ---------------------------------------------------------------------------
# ScheduleSimResult.as_dict (figure / JSON emission parity with SimResult)
# ---------------------------------------------------------------------------


class TestScheduleAsDict:
    def test_shape_and_content(self):
        sub = pair_substrate()
        a = sub.view(np.array([1000.0, 500.0]), 1.0)
        b = sub.view(np.array([500.0, 1000.0]), 1.0)
        res = simulate_schedule([(a, uniform_plan(a)), (b, uniform_plan(b))],
                                substrate=sub)
        d = res.as_dict()
        assert set(d) == {"makespan", "jobs", "utilization", "resources"}
        assert d["makespan"] == res.makespan
        assert len(d["jobs"]) == 2
        for job_dict, sim in zip(d["jobs"], res.jobs):
            assert job_dict == sim.as_dict()
        assert set(d["utilization"]) == set(sub.resources())
        assert set(d["resources"]) == set(sub.resources())
        for stats in d["resources"].values():
            assert {"busy_s", "waited_s", "volume_mb", "n_chunks",
                    "n_jobs"} <= set(stats)

    def test_json_serializable(self):
        import json

        sub = pair_substrate()
        v = sub.view(np.array([1000.0, 500.0]), 1.0)
        d = simulate_schedule([(v, uniform_plan(v))], substrate=sub).as_dict()
        json.dumps(d)  # must not raise
