"""Tests for the discrete-event executor: model agreement, barrier
semantics, fault tolerance, and the paper's dynamic mechanisms."""
import numpy as np
import pytest

from repro.core.makespan import BARRIERS_GGL, makespan
from repro.core.optimize import optimize_plan
from repro.core.plan import uniform_plan
from repro.core.platform import FailureEvent, planetlab_platform
from repro.core.simulate import SimConfig, simulate


@pytest.fixture(scope="module")
def platform():
    return planetlab_platform(8, alpha=1.0, seed=0)


class TestModelAgreement:
    def test_global_barriers_exact(self, platform):
        """With global barriers, chunk serialization changes nothing: the
        executor reproduces the analytic model exactly."""
        plan = uniform_plan(platform)
        for barriers in [("G", "G", "G"), ("G", "G", "L")]:
            model = makespan(platform, plan, barriers)
            sim = simulate(
                platform, plan, SimConfig(chunk_mb=32.0, barriers=barriers)
            ).makespan
            assert sim == pytest.approx(model, rel=1e-6)

    def test_pipelined_close_to_model(self, platform):
        """Fully pipelined execution serializes chunks, so it can only be
        slower than the (optimistic, fully-overlapped) model — but not by
        much at small chunk sizes."""
        plan = uniform_plan(platform)
        model = makespan(platform, plan, ("P", "P", "P"))
        sim = simulate(
            platform, plan, SimConfig(chunk_mb=16.0, barriers=("P", "P", "P"))
        ).makespan
        assert model <= sim <= model * 1.25

    def test_smaller_chunks_approach_model(self, platform):
        plan = uniform_plan(platform)
        model = makespan(platform, plan, ("P", "P", "P"))
        gaps = []
        for chunk in [128.0, 32.0, 8.0]:
            sim = simulate(
                platform, plan, SimConfig(chunk_mb=chunk, barriers=("P", "P", "P"))
            ).makespan
            gaps.append(sim / model - 1.0)
        assert gaps[0] >= gaps[-1] - 1e-9  # finer chunks, closer to model


class TestFaultTolerance:
    def test_mapper_failure_recovers_all_work(self, platform):
        plan = optimize_plan(platform, "e2e_multi", n_restarts=6, steps=250).plan
        healthy = simulate(platform, plan, SimConfig(barriers=BARRIERS_GGL))
        # kill the busiest mapper early in the run
        victim = int(np.argmax(plan.x.sum(axis=0)))
        failed = simulate(
            platform,
            plan,
            SimConfig(barriers=BARRIERS_GGL,
                      failures=[FailureEvent.mapper_kill(victim, 1.0)]),
        )
        assert failed.recovered_chunks > 0
        assert failed.makespan >= healthy.makespan  # recovery is not free
        assert np.isfinite(failed.makespan)  # ... but the job completes

    def test_failure_with_zero_assigned_work_is_noop(self, platform):
        plan = uniform_plan(platform)
        # failing after completion changes nothing
        done = simulate(platform, plan, SimConfig(barriers=BARRIERS_GGL)).makespan
        failed = simulate(
            platform,
            plan,
            SimConfig(barriers=BARRIERS_GGL,
                      failures=[FailureEvent.mapper_kill(0, done * 10)]),
        )
        assert failed.makespan == pytest.approx(done, rel=1e-9)
        assert failed.recovered_chunks == 0


class TestDynamics:
    def test_speculation_mitigates_straggler_on_lan(self):
        """An 8x compute straggler in a homogeneous LAN cluster: speculation
        must reclaim most of the loss (the planner did not know about the
        slowdown, and relocation is free on a LAN)."""
        p = planetlab_platform(1, alpha=0.1, seed=0)
        plan = uniform_plan(p)
        strag = {("m", 0): 8.0}
        base = simulate(
            p, plan,
            SimConfig(barriers=BARRIERS_GGL, stragglers=strag, chunk_mb=16.0),
        ).makespan
        spec = simulate(
            p, plan,
            SimConfig(barriers=BARRIERS_GGL, stragglers=strag,
                      speculation=True, chunk_mb=16.0),
        ).makespan
        assert spec < base * 0.7

    def test_speculation_can_hurt_over_wan(self, platform):
        """Paper §4.6.4: dynamic relocation over a heterogeneous WAN can
        *degrade* performance by moving intermediate data onto slow shuffle
        links — reproduce that effect qualitatively."""
        plan = uniform_plan(platform)
        strag = {("m", 0): 6.0}
        base = simulate(
            platform, plan, SimConfig(barriers=BARRIERS_GGL, stragglers=strag)
        )
        spec = simulate(
            platform, plan,
            SimConfig(barriers=BARRIERS_GGL, stragglers=strag, speculation=True),
        )
        # map time improves ...
        assert spec.phases()["map"] <= base.phases()["map"]
        # ... but the relocated output pays on the shuffle links
        assert spec.phases()["shuffle"] >= base.phases()["shuffle"]

    def test_dynamics_never_lose_chunks(self, platform):
        plan = uniform_plan(platform)
        for cfg in [
            SimConfig(barriers=BARRIERS_GGL, speculation=True, stealing=True,
                      stragglers={("m", 1): 8.0}),
            SimConfig(barriers=BARRIERS_GGL, speculation=True,
                      failures=[FailureEvent.mapper_kill(2, 2.0)]),
        ]:
            r = simulate(platform, plan, cfg)
            assert np.isfinite(r.makespan) and r.makespan > 0

    def test_replication_slows_push(self, platform):
        plan = uniform_plan(platform)
        r1 = simulate(platform, plan, SimConfig(barriers=BARRIERS_GGL, replication=1))
        r3 = simulate(
            platform,
            plan,
            SimConfig(
                barriers=BARRIERS_GGL,
                replication=3,
                cross_cluster_replication=True,
            ),
        )
        # paper §4.6.5: wide-area replication substantially increases push cost
        assert r3.push_end > r1.push_end
        assert r3.wasted_mb > 0

    def test_noise_determinism(self, platform):
        plan = uniform_plan(platform)
        cfg = SimConfig(barriers=BARRIERS_GGL, compute_noise=0.2, seed=42)
        a = simulate(platform, plan, cfg).makespan
        b = simulate(platform, plan, cfg).makespan
        assert a == b
