"""Tests for the FailureTrace fault subsystem: the redesigned
fault/SimConfig API (deprecated spellings stay byte-identical), typed
failure injection with conservation, replica re-execution, recovery-aware
residual pricing, and the ``reactive_failover`` online policy."""
import dataclasses
import json

import numpy as np
import pytest

from repro.api import GeoJob, GeoSchedule
from repro.core.makespan import BARRIERS_GGL, CostModel
from repro.core.optimize import (
    OnlineConfig,
    available_online_policies,
    get_online_config,
)
from repro.core.plan import ExecutionPlan, uniform_plan
from repro.core.platform import FailureEvent, FailureTrace, Substrate, \
    planetlab_platform
from repro.core.simulate import SimConfig, open_schedule, simulate, \
    simulate_schedule


def pair_substrate() -> Substrate:
    """Two single-node clusters over a thin WAN — failures on one side
    force traffic (or recovery) across the slow cut."""
    return Substrate(
        B_sm=np.array([[200.0, 1.0], [1.0, 200.0]]),
        B_mr=np.array([[200.0, 2.0], [2.0, 200.0]]),
        C_m=np.array([100.0, 100.0]),
        C_r=np.array([80.0, 80.0]),
        cluster_s=np.array([0, 1]),
        cluster_m=np.array([0, 1]),
        cluster_r=np.array([0, 1]),
        name="pair",
    )


# ---------------------------------------------------------------------------
# the redesigned SimConfig API: deprecated spellings normalize, warn, and
# stay byte-identical
# ---------------------------------------------------------------------------


class TestDeprecatedSpellings:
    def test_fail_mapper_tuple_warns_and_normalizes(self):
        with pytest.warns(DeprecationWarning, match="fail_mapper"):
            old = SimConfig(barriers=BARRIERS_GGL, fail_mapper=(1, 7.0))
        new = SimConfig(barriers=BARRIERS_GGL,
                        failures=[FailureEvent.mapper_kill(1, 7.0)])
        # both spellings collapse onto the same canonical state
        assert old.fail_mapper is None
        assert old.failures == (FailureEvent.mapper_kill(1, 7.0),)
        assert old == new

    def test_fail_mapper_tuple_byte_identical_result(self):
        p = planetlab_platform(4, alpha=1.0, seed=3)
        plan = uniform_plan(p)
        with pytest.warns(DeprecationWarning, match="fail_mapper"):
            old = simulate(p, plan, SimConfig(barriers=BARRIERS_GGL,
                                              fail_mapper=(0, 5.0)))
        new = simulate(p, plan, SimConfig(
            barriers=BARRIERS_GGL,
            failures=[FailureEvent.mapper_kill(0, 5.0)]))
        assert old.as_dict() == new.as_dict()

    def test_vectorized_flag_warns_and_maps_to_mode(self):
        with pytest.warns(DeprecationWarning, match="event_vec"):
            old = SimConfig(vectorized=True)
        assert old.mode == "event_vec" and old.vectorized is False
        assert old == SimConfig(mode="event_vec")

    def test_vectorized_flag_byte_identical_result(self):
        p = planetlab_platform(4, alpha=1.0, seed=3)
        plan = uniform_plan(p)
        with pytest.warns(DeprecationWarning, match="event_vec"):
            cfg = SimConfig(chunk_mb=32.0, vectorized=True, audit=True)
        old = simulate_schedule([(p, plan, cfg)])
        new = simulate_schedule([(p, plan, SimConfig(
            chunk_mb=32.0, mode="event_vec", audit=True))])
        assert old.violations == [] and old.as_dict() == new.as_dict()

    def test_vectorized_conflicts_with_fluid(self):
        with pytest.warns(DeprecationWarning, match="event_vec"):
            with pytest.raises(ValueError, match="conflicts"):
                SimConfig(vectorized=True, mode="fluid")

    def test_cluster_partition_is_not_a_per_job_fault(self):
        with pytest.raises(ValueError, match="Substrate.with_failures"):
            SimConfig(failures=[
                FailureEvent.cluster_partition(0, 10.0, 20.0)])

    def test_failures_entries_type_checked(self):
        with pytest.raises(TypeError, match="FailureEvent"):
            SimConfig(failures=[(0, 10.0)])


# ---------------------------------------------------------------------------
# the FailureTrace on the substrate
# ---------------------------------------------------------------------------


class TestFailureTrace:
    def test_with_failures_sorts_and_exposes_times(self):
        sub = pair_substrate().with_failures([
            FailureEvent.reducer_kill(1, 50.0),
            FailureEvent.cluster_partition(0, 10.0, 30.0),
        ])
        assert isinstance(sub.failures, FailureTrace)
        assert sub.failure_times() == (10.0, 30.0, 50.0)

    def test_at_folds_failures_into_capacities(self):
        sub = pair_substrate().with_failures([
            FailureEvent.reducer_kill(1, 50.0),
        ])
        before, after = sub.at(49.0), sub.at(51.0)
        assert after.C_r[1] < before.C_r[1] * 1e-2
        assert after.C_r[0] == before.C_r[0]


# ---------------------------------------------------------------------------
# conservation through every failure mechanism
# ---------------------------------------------------------------------------


class TestFailureConservation:
    def test_reducer_kill_claws_back_and_reemits(self):
        p = planetlab_platform(4, alpha=1.0, seed=3)
        plan = uniform_plan(p)
        healthy = simulate(p, plan, SimConfig(barriers=BARRIERS_GGL))
        t_kill = healthy.shuffle_end * 0.6  # mid-shuffle
        res = simulate_schedule([(p, plan, SimConfig(
            barriers=BARRIERS_GGL, audit=True,
            failures=[FailureEvent.reducer_kill(0, t_kill)]))])
        j = res.jobs[0]
        assert res.violations == []
        assert j.lost_mb > 0
        assert j.lost_mb == pytest.approx(j.reexec_mb, rel=1e-6)
        assert np.isfinite(res.makespan)
        assert res.makespan >= healthy.makespan

    def test_per_job_and_substrate_kill_identical(self):
        """A substrate-wide reducer_kill on a single-job schedule is the
        same fault as the per-job spelling — byte-for-byte."""
        sub = pair_substrate()
        v = sub.view(np.array([2000.0, 2000.0]), 1.0, name="job")
        plan = uniform_plan(v)
        per_job = simulate_schedule(
            [(v, plan, SimConfig(
                barriers=BARRIERS_GGL, audit=True,
                failures=[FailureEvent.reducer_kill(1, 23.4)]))],
            substrate=sub)
        fabric = simulate_schedule(
            [(v, plan, SimConfig(barriers=BARRIERS_GGL, audit=True))],
            substrate=sub.with_failures(
                [FailureEvent.reducer_kill(1, 23.4)]))
        assert per_job.violations == [] and fabric.violations == []
        assert per_job.as_dict() == fabric.as_dict()

    def test_partition_parks_and_resumes(self):
        """A cluster partition dooms in-flight cross-cut transfers and
        parks queued ones; repair re-transmits them — conserved, and the
        makespan grows with the outage length."""
        sub = pair_substrate()
        v = sub.view(np.array([2000.0, 2000.0]), 1.0, name="job")
        plan = uniform_plan(v)
        spans = []
        for t_repair in (40.0, 120.0):
            res = simulate_schedule(
                [(v, plan, SimConfig(barriers=BARRIERS_GGL, audit=True))],
                substrate=sub.with_failures([
                    FailureEvent.cluster_partition(0, 10.0, t_repair)]))
            j = res.jobs[0]
            assert res.violations == []
            assert j.lost_mb == pytest.approx(j.reexec_mb, rel=1e-6)
            spans.append(res.makespan)
        assert spans[1] > spans[0]

    def test_failure_after_completion_is_noop(self):
        p = planetlab_platform(2, alpha=1.0, seed=0)
        plan = uniform_plan(p)
        done = simulate(p, plan, SimConfig(barriers=BARRIERS_GGL))
        late = simulate(p, plan, SimConfig(
            barriers=BARRIERS_GGL, audit=True,
            failures=[FailureEvent.reducer_kill(0, done.makespan * 10)]))
        assert late.makespan == pytest.approx(done.makespan, rel=1e-9)
        assert late.lost_mb == 0.0 and late.reexec_mb == 0.0


# ---------------------------------------------------------------------------
# replica re-execution
# ---------------------------------------------------------------------------


class TestReplicaRecovery:
    def test_replica_promotion_beats_source_repush(self):
        """With replication>=2 a mapper kill promotes the surviving
        replica *locally*: the recovery penalty must be a small fraction
        of what re-pushing the lost volume over the thin source links
        would cost."""
        sub = Substrate(
            B_sm=np.array([[5.0, 5.0]]),
            B_mr=np.array([[200.0, 200.0], [200.0, 200.0]]),
            C_m=np.array([100.0, 100.0]),
            C_r=np.array([80.0, 80.0]),
            cluster_s=np.array([0]),
            cluster_m=np.array([1, 1]),
            cluster_r=np.array([1, 1]),
            name="replicated",
        )
        v = sub.view(np.array([1000.0]), 1.0, name="job")
        plan = uniform_plan(v)
        base = dict(barriers=BARRIERS_GGL, chunk_mb=64.0, replication=2,
                    audit=True)
        healthy = simulate_schedule([(v, plan, SimConfig(**base))],
                                    substrate=sub)
        t_kill = healthy.jobs[0].push_end + 2.3  # mid-map, push complete
        failed = simulate_schedule([(v, plan, SimConfig(
            failures=[FailureEvent.mapper_kill(0, t_kill)], **base))],
            substrate=sub)
        j = failed.jobs[0]
        assert failed.violations == []
        assert j.recovered_chunks > 0
        assert j.lost_mb > 0
        assert j.lost_mb == pytest.approx(j.reexec_mb, rel=1e-6)
        repush_s = j.lost_mb / float(np.asarray(sub.B_sm).sum())
        penalty = failed.makespan - healthy.makespan
        assert penalty < 0.5 * repush_s


# ---------------------------------------------------------------------------
# recovery-aware residual pricing
# ---------------------------------------------------------------------------


class TestPostFailurePricing:
    def test_post_failure_snapshot_prices_like_des_replay(self):
        """Under all-global barriers the DES is exact against the analytic
        model, so the post-failure snapshot priced through
        price_residual_shared must agree with the engine's own remaining
        time to 1e-6 — the planner's view of a broken schedule is the
        executor's."""
        sub = Substrate(
            B_sm=np.array([[100.0]]),
            B_mr=np.array([[50.0, 50.0]]),
            C_m=np.array([80.0]),
            C_r=np.array([40.0, 40.0]),
            cluster_s=np.zeros(1, dtype=int),
            cluster_m=np.zeros(1, dtype=int),
            cluster_r=np.array([0, 1]),
            name="pricing",
        )
        v = sub.view(np.array([1000.0]), 1.0, name="job")
        # everything on r1; its death forces a full re-emission to r0
        plan = ExecutionPlan(x=np.ones((1, 1)), y=np.array([0.0, 1.0]))
        barriers = ("G", "G", "G")
        t_kill = 51.7  # mid-reduce at r1; r0 and every link are idle
        eng = open_schedule(
            [(v, plan, SimConfig(
                barriers=barriers, chunk_mb=64.0, audit=True,
                failures=[FailureEvent.reducer_kill(1, t_kill)]))],
            substrate=sub)
        eng.run_until(t_kill, inclusive=True)
        prog = eng.snapshot().jobs[0]
        assert not prog.red_alive[1] and prog.red_alive[0]
        cm = CostModel(v, barriers)
        priced_shared = float(
            cm.price_residual_shared([prog], [plan])[0]["makespan"])
        priced_solo = cm.residual_makespan(prog, plan)
        res = eng.run()
        actual = res.makespan - t_kill
        assert res.violations == []
        assert priced_shared == pytest.approx(actual, abs=1e-6)
        assert priced_solo == pytest.approx(actual, abs=1e-6)

    def test_undeliver_reducer_moves_landed_back_to_pool(self):
        from repro.core.makespan import JobProgress
        p = planetlab_platform(2, alpha=1.0, seed=0)
        fresh = JobProgress.fresh(p)
        prog = dataclasses.replace(
            fresh,
            at_reducer=np.array([30.0, 10.0] + [0.0] * (p.nR - 2)),
        )
        undone = prog.undeliver_reducer(1)
        assert not undone.red_alive[1]
        assert float(undone.at_reducer[1]) == 0.0
        assert float(undone.shuffle_pool.sum()) == pytest.approx(
            float(prog.shuffle_pool.sum()) + 10.0)


# ---------------------------------------------------------------------------
# the online loop: reactive_failover, speculation-as-a-knob, frozen gate
# ---------------------------------------------------------------------------


class TestOnlineFailover:
    def test_reactive_failover_policy_registered(self):
        assert "reactive_failover" in available_online_policies()
        ocfg = get_online_config("reactive_failover")
        assert ocfg.shared is True
        assert ocfg.speculation is True

    def test_set_speculation_flips_the_knob_online(self):
        p = planetlab_platform(2, alpha=1.0, seed=0)
        eng = open_schedule([(p, uniform_plan(p), SimConfig())])
        assert eng.runs[0].cfg.speculation is False
        eng.set_speculation(0, True)
        assert eng.runs[0].cfg.speculation is True
        eng.set_speculation(0, False, threshold=2.0)
        assert eng.runs[0].cfg.speculation is False
        assert eng.runs[0].cfg.spec_threshold == 2.0

    def test_infinite_hysteresis_with_failures_is_static(self):
        """hysteresis=inf freezes the control gate: an online run through
        a mapper kill plus a substrate reducer kill reproduces the frozen
        schedule byte-for-byte."""
        sub = pair_substrate().with_failures(
            [FailureEvent.reducer_kill(1, 23.4)])
        v = sub.view(np.array([2000.0, 2000.0]), 1.0, name="job")
        plan = uniform_plan(v)
        cfg = SimConfig(barriers=BARRIERS_GGL, chunk_mb=128.0,
                        failures=[FailureEvent.mapper_kill(0, 11.2)])
        sched = GeoSchedule(
            [GeoJob(v).with_plan(plan, BARRIERS_GGL)]).with_plans()
        report = sched.run_online(policy="reactive", cfg=cfg,
                                  online=OnlineConfig(hysteresis=np.inf))
        ref = simulate_schedule([(v, plan, cfg)], substrate=sub)
        assert report.swaps == ()
        assert report.makespan_online == ref.makespan
        for got, want in zip(report.sim.jobs, ref.jobs):
            assert got.phases() == want.phases()
            assert got.lost_mb == want.lost_mb
            assert got.reexec_mb == want.reexec_mb

    def test_online_report_as_dict_is_json_pure(self):
        sub = pair_substrate().with_failures(
            [FailureEvent.reducer_kill(1, 23.4)])
        v = sub.view(np.array([2000.0, 2000.0]), 1.0, name="job")
        plan = uniform_plan(v)
        sched = GeoSchedule(
            [GeoJob(v).with_plan(plan, BARRIERS_GGL)]).with_plans()
        report = sched.run_online(
            policy="reactive_failover",
            cfg=SimConfig(barriers=BARRIERS_GGL, chunk_mb=128.0),
            n_restarts=2, steps=40)
        d = json.loads(json.dumps(report.as_dict()))
        assert d["policy"] == "reactive_failover"
        assert d["makespan_online"] == report.makespan_online
        assert d["n_decisions"] == len(report.decisions)
        assert d["n_failures_observed"] >= 1
        assert {"time", "event", "job", "action"} <= set(d["decisions"][0])
