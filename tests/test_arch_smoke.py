"""Per-architecture smoke tests: instantiate the REDUCED config of each
assigned arch, run one forward + one train step on CPU, assert output
shapes and no NaNs; run a prefill→decode roundtrip for the serving path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import model as M

ARCH_IDS = sorted(ARCHS)


def _batch(cfg, key, B=2, T=32):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.frontend == "embed":
        batch["embeds"] = jax.random.normal(ks[0], (B, T, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, T), 0, cfg.vocab)
    batch["labels"] = jax.random.randint(ks[1], (B, T), 0, cfg.vocab)
    return batch


@pytest.fixture(scope="module")
def setups():
    out = {}
    for name in ARCH_IDS:
        cfg = ARCHS[name].reduced()
        params = M.init(cfg, jax.random.PRNGKey(0))
        out[name] = (cfg, params)
    return out


@pytest.mark.parametrize("name", ARCH_IDS)
def test_forward_shapes_and_finite(setups, name):
    cfg, params = setups[name]
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, cache, aux = M.forward(cfg, params, batch)
    B, T = (2, 32)
    assert logits.shape == (B, T, cfg.vocab)
    assert cache is None
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize(
    "name",
    [
        pytest.param(
            n,
            marks=pytest.mark.xfail(
                strict=True,
                reason=(
                    "llama4-scout is the only top-1 MoE here (reduced() "
                    "keeps top_k=1): expert assignment is a hard argmax, so "
                    "the loss is piecewise in the router params and this "
                    "test's fixed 0.5-LR SGD step crosses an assignment "
                    "boundary (tokens land on differently-trained experts "
                    "and the re-evaluated loss rises 6.213→6.230). "
                    "Deterministic — the same step passes at lr<=0.45 and "
                    "for every top-k>=2 arch (granite-moe is top-8)."
                ),
            ),
        )
        if n == "llama4-scout-17b-a16e"
        else n
        for n in ARCH_IDS
    ],
)
def test_train_step_reduces_loss(setups, name):
    """One SGD step on a fixed batch must not produce NaNs and must reduce
    the loss on that same batch (sanity of the whole grad path)."""
    cfg, params = setups[name]
    batch = _batch(cfg, jax.random.PRNGKey(2))

    @jax.jit
    def step(p):
        (l, metrics), g = jax.value_and_grad(
            lambda p_: M.loss_fn(cfg, p_, batch), has_aux=True
        )(p)
        p2 = jax.tree.map(lambda a, b: a - 0.5 * b, p, g)
        return l, p2

    l0, p1 = step(params)
    l1, _ = step(p1)
    assert np.isfinite(float(l0)) and np.isfinite(float(l1))
    assert float(l1) < float(l0), (name, float(l0), float(l1))
    # gradients flowed into every parameter group
    flat = jax.tree_util.tree_leaves(
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, p1)
    )
    assert sum(1 for v in flat if v > 0) > len(flat) * 0.5


@pytest.mark.parametrize("name", ARCH_IDS)
def test_prefill_decode_matches_full_forward(setups, name):
    """prefill(T) then decode one token == forward(T+1): the cache path is
    numerically consistent with the parallel path.

    MoE capacity is a function of the total token count, so prefill(T) and
    forward(T+1) legitimately drop different tokens at tight capacity; the
    consistency check uses ample capacity (no drops) to isolate the cache
    semantics."""
    import dataclasses

    cfg, params = setups[name]
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    B, T = 2, 16
    key = jax.random.PRNGKey(3)
    if cfg.frontend == "embed":
        embeds = jax.random.normal(key, (B, T + 1, cfg.d_model))
        full_b = {"embeds": embeds}
        pre_b = {"embeds": embeds[:, :T]}
        dec_b = {"embeds": embeds[:, T:]}
    else:
        toks = jax.random.randint(key, (B, T + 1), 0, cfg.vocab)
        full_b = {"tokens": toks}
        pre_b = {"tokens": toks[:, :T]}
        dec_b = {"tokens": toks[:, T:]}
    logits_full, _, _ = M.forward(cfg, params, full_b)
    logits_pre, cache, _ = M.prefill(cfg, params, pre_b, max_cache_len=T + 8)
    np.testing.assert_allclose(
        np.asarray(logits_pre), np.asarray(logits_full[:, :T]),
        atol=2e-3, rtol=2e-3,
    )
    dec_b["positions"] = jnp.full((B, 1), T, jnp.int32)
    logits_dec, cache2, _ = M.decode_step(cfg, params, dec_b, cache)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(logits_full[:, T]),
        atol=2e-3, rtol=2e-3,
    )


@pytest.mark.parametrize("name", ARCH_IDS)
def test_remat_matches(setups, name):
    cfg, params = setups[name]
    batch = _batch(cfg, jax.random.PRNGKey(4))
    l_plain, _ = M.loss_fn(cfg, params, batch, remat=False)
    l_remat, _ = M.loss_fn(cfg, params, batch, remat=True)
    np.testing.assert_allclose(float(l_plain), float(l_remat), rtol=1e-5)


def test_param_counts_match_reported_sizes():
    """Sanity: full-config parameter counts land near the published sizes
    (total params; loose bands — configs are from public cards)."""
    bands = {
        "llama4-scout-17b-a16e": (80e9, 120e9),  # 16 full experts/layer
        "mistral-nemo-12b": (10e9, 14e9),
        "falcon-mamba-7b": (6e9, 9e9),
        "recurrentgemma-9b": (7e9, 11e9),
        "olmo-1b": (0.9e9, 1.6e9),
        "qwen3-1.7b": (1.2e9, 2.3e9),
        "stablelm-1.6b": (1.2e9, 2.1e9),
        "phi-3-vision-4.2b": (3.4e9, 4.5e9),
        "musicgen-large": (2.6e9, 3.9e9),
        "granite-moe-3b-a800m": (2.2e9, 3.9e9),
    }
    for name, (lo, hi) in bands.items():
        n = ARCHS[name].n_params()
        assert lo <= n <= hi, (name, f"{n:.3e}")


def test_active_params_less_than_total_for_moe():
    for name in ["llama4-scout-17b-a16e", "granite-moe-3b-a800m"]:
        cfg = ARCHS[name]
        assert cfg.n_active_params() < cfg.n_params() * 0.6
