"""Shared test setup: make ``python -m pytest`` work from a fresh checkout
without the ``PYTHONPATH=src`` incantation by prepending ``src/`` to
``sys.path`` (mirrors the ``[tool.pytest.ini_options] pythonpath`` entry in
pyproject.toml, for runners that bypass the ini file)."""
import os
import sys

_SRC = os.path.abspath(
    os.path.join(os.path.dirname(os.path.abspath(__file__)), os.pardir, "src")
)
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
