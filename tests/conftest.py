"""Shared test setup: make ``python -m pytest`` work from a fresh checkout
without the ``PYTHONPATH=src`` incantation by prepending ``src/`` to
``sys.path`` (mirrors the ``[tool.pytest.ini_options] pythonpath`` entry in
pyproject.toml, for runners that bypass the ini file).  The repo root is
added too, so tests can import scenario builders from the ``benchmarks``
package (e.g. ``tests/test_replan_shared.py``) from any cwd."""
import os
import sys

_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(os.path.abspath(__file__)), os.pardir)
)
for _path in (os.path.join(_ROOT, "src"), _ROOT):
    if _path not in sys.path:
        sys.path.insert(0, _path)
