"""Tests for the makespan model — including the paper's §1.3 worked example."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.makespan import (
    BARRIERS_ALL_GLOBAL,
    BARRIERS_ALL_PIPELINED,
    makespan,
    phase_breakdown,
)
from repro.core.plan import ExecutionPlan, local_push_plan, uniform_plan
from repro.core.platform import (
    planetlab_platform,
    two_cluster_example,
)

GB = 1000.0  # MB


class TestPaperWorkedExample:
    """§1.3: the two-cluster example, closed-form numbers from the text."""

    def test_homogeneous_uniform_push(self):
        # alpha=1, all links 100 MB/s, compute 100 MB/s: uniform placement.
        p = two_cluster_example(alpha=1.0, nonlocal_bw=100.0)
        up = uniform_plan(p)
        # push_end per mapper = max(75GB, 25GB)/100MBps = 750 s
        assert phase_breakdown(p, up)["push"] == pytest.approx(750.0)

    def test_slow_nonlocal_links_favor_local_push(self):
        p = two_cluster_example(alpha=1.0, nonlocal_bw=10.0)
        lp = local_push_plan(p)
        up = uniform_plan(p)
        # paper: local push = 150 GB / 100 MBps = 1500 s
        assert phase_breakdown(p, lp)["push"] == pytest.approx(1500.0)
        # paper: uniform push = 75 GB / 10 MBps = 7500 s
        assert phase_breakdown(p, up)["push"] == pytest.approx(7500.0)
        # map phase for uniform is smaller by 50GB/100MBps = 500 s
        map_local = phase_breakdown(p, lp)["map"]
        map_uniform = phase_breakdown(p, up)["map"]
        assert map_local - map_uniform == pytest.approx(500.0)
        # ... but local push still wins end-to-end
        assert makespan(p, lp) < makespan(p, up)

    def test_large_alpha_prefers_consolidation(self):
        # alpha=10: pushing D2 to M1 and reducing all in cluster 1 avoids
        # non-local traffic in the communication-heavy shuffle.
        p = two_cluster_example(alpha=10.0, nonlocal_bw=10.0)
        consolidated = ExecutionPlan(
            x=np.array([[1.0, 0.0], [1.0, 0.0]]), y=np.array([1.0, 0.0])
        )
        lp = local_push_plan(p)
        assert makespan(p, consolidated) < makespan(p, lp)
        # and the local push *is* push-myopically optimal despite losing e2e
        assert phase_breakdown(p, lp)["push"] <= phase_breakdown(p, consolidated)["push"]


class TestBarrierSemantics:
    @pytest.mark.parametrize("alpha", [0.1, 1.0, 10.0])
    def test_relaxation_never_hurts(self, alpha):
        """P ≤ L ≤ G at every boundary, for any fixed plan (more overlap can
        only shrink the modeled makespan)."""
        p = planetlab_platform(8, alpha=alpha, seed=3)
        plan = uniform_plan(p)
        order = {"G": 2, "L": 1, "P": 0}
        import itertools

        for b1 in itertools.product("GLP", repeat=3):
            for b2 in itertools.product("GLP", repeat=3):
                if all(order[a] >= order[b] for a, b in zip(b1, b2)):
                    assert makespan(p, plan, b1) >= makespan(p, plan, b2) - 1e-6

    def test_global_barrier_decomposes_sequentially(self):
        p = planetlab_platform(8, alpha=1.0, seed=0)
        plan = uniform_plan(p)
        bd = phase_breakdown(p, plan, BARRIERS_ALL_GLOBAL)
        assert bd["push"] + bd["map"] + bd["shuffle"] + bd["reduce"] == pytest.approx(
            bd["makespan"], rel=1e-6
        )

    def test_smooth_is_upper_bound(self):
        p = planetlab_platform(8, alpha=1.0, seed=1)
        plan = uniform_plan(p)
        hard = makespan(p, plan, BARRIERS_ALL_GLOBAL)
        for tau in [1.0, 10.0, 100.0]:
            smooth = makespan(p, plan, BARRIERS_ALL_GLOBAL, tau=tau)
            assert smooth >= hard - 1e-4
        # and converges as tau -> 0
        assert makespan(p, plan, BARRIERS_ALL_GLOBAL, tau=1e-3) == pytest.approx(
            hard, rel=1e-4
        )


class TestModelProperties:
    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        alpha=st.floats(0.05, 12.0),
        scale=st.floats(1.1, 4.0),
    )
    def test_more_bandwidth_never_slower(self, seed, alpha, scale):
        p = planetlab_platform(8, alpha=alpha, seed=seed % 17)
        plan = uniform_plan(p)
        import dataclasses

        faster = dataclasses.replace(
            p, B_sm=p.B_sm * scale, B_mr=p.B_mr * scale
        )
        for barriers in [BARRIERS_ALL_GLOBAL, BARRIERS_ALL_PIPELINED]:
            assert makespan(faster, plan, barriers) <= makespan(p, plan, barriers) + 1e-6

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), scale=st.floats(1.1, 4.0))
    def test_more_compute_never_slower(self, seed, scale):
        p = planetlab_platform(8, alpha=1.0, seed=seed % 17)
        plan = uniform_plan(p)
        import dataclasses

        faster = dataclasses.replace(p, C_m=p.C_m * scale, C_r=p.C_r * scale)
        assert makespan(faster, plan) <= makespan(p, plan) + 1e-6

    @settings(max_examples=25, deadline=None)
    @given(a1=st.floats(0.1, 5.0), a2=st.floats(0.1, 5.0))
    def test_monotone_in_alpha(self, a1, a2):
        """More intermediate data can never make a fixed plan faster."""
        lo, hi = sorted([a1, a2])
        p = planetlab_platform(8, alpha=lo, seed=5)
        plan = uniform_plan(p)
        assert makespan(p.with_alpha(hi), plan) >= makespan(p, plan) - 1e-6

    def test_scale_invariance(self):
        """Scaling all data sizes by c scales the makespan by c."""
        import dataclasses

        p = planetlab_platform(8, alpha=1.0, seed=2)
        plan = uniform_plan(p)
        p2 = dataclasses.replace(p, D=p.D * 3.0)
        assert makespan(p2, plan) == pytest.approx(3.0 * makespan(p, plan), rel=1e-6)
