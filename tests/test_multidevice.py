"""Multi-device semantics tests.

These run in subprocesses with ``--xla_force_host_platform_device_count=8``
(the flag must be set before jax initializes, and the main test process
must keep seeing 1 device), covering:

* expert-parallel MoE via shard_map == single-device reference,
* the hierarchical (pod, data) all-reduce == plain tree-sum,
* a reduced-config dry-run cell on a tiny mesh (the same machinery the
  512-device production sweep uses),
* elastic checkpoint re-shard: save sharded on a 2x4 mesh, restore on 1.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, n_devices: int = 8) -> str:
    code = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={n_devices}'\n"
        + textwrap.dedent(body)
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=560,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_moe_shard_map_matches_single_device():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import ARCHS
        from repro.models import layers as L
        from repro.models import model as M
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = dataclasses.replace(
            ARCHS["granite-moe-3b-a800m"].reduced(), capacity_factor=8.0)
        key = jax.random.PRNGKey(0)
        p = L.init_moe(cfg, key, tp=4)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))

        y_ref, aux_ref = L.moe_fwd(cfg, p, x, mesh=None)

        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
        y_ep, aux_ep = jax.jit(
            lambda pp, xx: L.moe_fwd(cfg, pp, xx, mesh=mesh))(p, xs)
        np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                                   atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(float(aux_ep), float(aux_ref), rtol=1e-4)
        print("MOE_OK")
    """)
    assert "MOE_OK" in out


def test_hierarchical_allreduce_matches_psum():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.train.collective_schedule import hierarchical_allreduce
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("pod", "data"))
        tree = {
            "a": jnp.arange(1000, dtype=jnp.float32).reshape(10, 100),
            "b": jnp.ones((7,), jnp.float32),
        }
        got = jax.jit(lambda t: hierarchical_allreduce(t, mesh, mean=False))(tree)
        # every device holds the same (replicated) tree: sum over 8 devices
        np.testing.assert_allclose(np.asarray(got["a"]),
                                   8.0 * np.asarray(tree["a"]), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(got["b"]), 8.0, rtol=1e-6)
        print("HIER_OK")
    """)
    assert "HIER_OK" in out


@pytest.mark.parametrize("arch,shape", [
    ("qwen3-1.7b", "train_4k"),
    ("granite-moe-3b-a800m", "train_4k"),
    ("falcon-mamba-7b", "decode_32k"),
])
def test_dryrun_cell_reduced_mesh(arch, shape):
    """The dry-run machinery (shardings, lowering, collective parsing) on a
    2x4 mesh with reduced configs — the exact code path of the production
    512-device sweep."""
    out = run_sub(f"""
        import jax, json
        from repro.launch.dryrun import run_cell
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        rep = run_cell({arch!r}, {shape!r}, multi_pod=False, mesh=mesh,
                       reduced=True)
        assert rep["hlo_flops_per_device"] > 0
        assert rep["per_device_bytes"] > 0
        print("CELL_OK", json.dumps(rep["collectives_per_device_bytes"]))
    """)
    assert "CELL_OK" in out


def test_elastic_checkpoint_reshard(tmp_path):
    """Save a train state sharded over a 2x4 mesh; restore it on a single
    device (different topology) and verify bitwise equality."""
    out = run_sub(f"""
        import jax, jax.numpy as jnp, numpy as np, functools
        from repro.configs import ARCHS, padded_for_tp
        from repro.models import model as M
        from repro.models.sharding import axis_rules, DEFAULT_RULES
        from repro.train.checkpoint import CheckpointManager
        from repro.train.train_step import init_state, state_shardings
        from jax.sharding import NamedSharding

        cfg = padded_for_tp(ARCHS["qwen3-1.7b"].reduced(), 4)
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        with axis_rules(mesh, DEFAULT_RULES):
            params = M.init(cfg, jax.random.PRNGKey(0), tp=4)
            state = init_state(cfg, params)
            sh = state_shardings(
                cfg, jax.tree.map(
                    lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state),
                mesh)
            state = jax.tree.map(jax.device_put, state, sh)
        mgr = CheckpointManager({str(tmp_path)!r}, keep=2)
        mgr.save(5, state)
        print("SAVED", mgr.steps())
    """)
    assert "SAVED [5]" in out
    # restore in THIS process (1 device — a different topology)
    import jax

    from repro.configs import ARCHS, padded_for_tp
    from repro.models import model as M
    from repro.train.checkpoint import CheckpointManager
    from repro.train.train_step import init_state

    cfg = padded_for_tp(ARCHS["qwen3-1.7b"].reduced(), 4)
    params = M.init(cfg, jax.random.PRNGKey(0), tp=4)
    like = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
        init_state(cfg, params),
    )
    mgr = CheckpointManager(str(tmp_path), keep=2)
    restored, _, step = mgr.restore(None, like)
    assert step == 5
    import numpy as np

    for a, b in zip(
        jax.tree_util.tree_leaves(restored.params),
        jax.tree_util.tree_leaves(params),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
