"""Per-kernel validation: shape/dtype sweeps asserting allclose against the
pure-jnp oracles in repro.kernels.ref (kernels run in interpret mode on CPU).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# guarded import: hypothesis is optional, property tests skip without it
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.moe_dispatch import compute_slots, moe_dispatch
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.segment_reduce import segment_sum


def _tol(dtype):
    return {"float32": 2e-5, "bfloat16": 2e-2}[jnp.dtype(dtype).name]


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,Hq,Hkv,T,S,Dh,causal,window,qoff",
        [
            (2, 4, 2, 128, 128, 64, True, None, 0),
            (1, 8, 8, 100, 100, 32, True, None, 0),  # non-block-aligned
            (1, 4, 1, 64, 256, 64, True, None, 192),  # chunked decode offset
            (2, 4, 2, 128, 128, 64, True, 48, 0),  # sliding window
            (1, 2, 2, 96, 200, 128, False, None, 0),  # non-causal
            (1, 16, 4, 256, 256, 64, True, 128, 0),  # GQA + window
        ],
    )
    def test_matches_reference(self, dtype, B, Hq, Hkv, T, S, Dh, causal, window, qoff):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, Hq, T, Dh), dtype)
        k = jax.random.normal(ks[1], (B, Hkv, S, Dh), dtype)
        v = jax.random.normal(ks[2], (B, Hkv, S, Dh), dtype)
        out = flash_attention(
            q, k, v, causal=causal, window=window, q_offset=qoff,
            block_q=32, block_k=32,
        )
        expect = ref.attention_ref(q, k, v, causal=causal, window=window, q_offset=qoff)
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(expect, np.float32),
            atol=_tol(dtype), rtol=1e-2,
        )

    def test_block_shape_independence(self):
        """Output must not depend on the BlockSpec tiling."""
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 4, 160, 64))
        k = jax.random.normal(ks[1], (1, 2, 160, 64))
        v = jax.random.normal(ks[2], (1, 2, 160, 64))
        outs = [
            flash_attention(q, k, v, block_q=bq, block_k=bk)
            for bq, bk in [(32, 32), (64, 32), (32, 80), (160, 160)]
        ]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=2e-5)


class TestMambaScan:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,T,Di,Ds,chunk", [(2, 64, 32, 8, 16), (1, 100, 64, 16, 32), (1, 33, 16, 4, 16)]
    )
    def test_matches_reference(self, dtype, B, T, Di, Ds, chunk):
        ks = jax.random.split(jax.random.PRNGKey(0), 6)
        x = jax.random.normal(ks[0], (B, T, Di), dtype)
        delta = jax.nn.softplus(jax.random.normal(ks[1], (B, T, Di), dtype))
        A = -jax.nn.softplus(jax.random.normal(ks[2], (Di, Ds)))
        Bc = jax.random.normal(ks[3], (B, T, Ds), dtype)
        Cc = jax.random.normal(ks[4], (B, T, Ds), dtype)
        D = jax.random.normal(ks[5], (Di,))
        y, hT = mamba_scan(x, delta, A, Bc, Cc, D, chunk=chunk, block_d=Di)
        y_ref, hT_ref = ref.mamba_scan_ref(x, delta, A, Bc, Cc, D)
        np.testing.assert_allclose(
            np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
            atol=_tol(dtype) * 5, rtol=3e-2,
        )
        np.testing.assert_allclose(
            np.asarray(hT), np.asarray(hT_ref), atol=_tol(dtype) * 5, rtol=3e-2
        )

    def test_stateful_equals_full(self):
        """Scanning two halves with carried state == scanning the whole."""
        ks = jax.random.split(jax.random.PRNGKey(2), 6)
        B, T, Di, Ds = 1, 64, 32, 8
        x = jax.random.normal(ks[0], (B, T, Di))
        delta = jax.nn.softplus(jax.random.normal(ks[1], (B, T, Di)))
        A = -jax.nn.softplus(jax.random.normal(ks[2], (Di, Ds)))
        Bc = jax.random.normal(ks[3], (B, T, Ds))
        Cc = jax.random.normal(ks[4], (B, T, Ds))
        D = jax.random.normal(ks[5], (Di,))
        y_full, h_full = mamba_scan(x, delta, A, Bc, Cc, D, chunk=16, block_d=Di)
        h = T // 2
        y1, s = mamba_scan(x[:, :h], delta[:, :h], A, Bc[:, :h], Cc[:, :h], D,
                           chunk=16, block_d=Di)
        y2, s2 = mamba_scan(x[:, h:], delta[:, h:], A, Bc[:, h:], Cc[:, h:], D,
                            h0=s, chunk=16, block_d=Di)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([y1, y2], axis=1)), np.asarray(y_full),
            atol=1e-4, rtol=1e-4,
        )
        np.testing.assert_allclose(np.asarray(s2), np.asarray(h_full), atol=1e-4, rtol=1e-4)


class TestRGLRUScan:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,T,D,chunk", [(2, 64, 32, 16), (1, 100, 64, 32), (1, 50, 16, 64)])
    def test_matches_reference(self, dtype, B, T, D, chunk):
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        x = jax.random.normal(ks[0], (B, T, D), dtype)
        a = jax.nn.sigmoid(jax.random.normal(ks[1], (B, T, D), dtype))
        y, hT = rglru_scan(x, a, chunk=chunk, block_d=D)
        y_ref, hT_ref = ref.rglru_scan_ref(x, a)
        np.testing.assert_allclose(
            np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
            atol=_tol(dtype) * 5, rtol=3e-2,
        )
        np.testing.assert_allclose(
            np.asarray(hT), np.asarray(hT_ref), atol=_tol(dtype) * 5, rtol=3e-2
        )

    def test_stateful_equals_full(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 2)
        B, T, D = 1, 48, 32
        x = jax.random.normal(ks[0], (B, T, D))
        a = jax.nn.sigmoid(jax.random.normal(ks[1], (B, T, D)))
        y_full, h_full = rglru_scan(x, a, chunk=16, block_d=D)
        y1, s = rglru_scan(x[:, :24], a[:, :24], chunk=16, block_d=D)
        y2, s2 = rglru_scan(x[:, 24:], a[:, 24:], h0=s, chunk=16, block_d=D)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([y1, y2], axis=1)), np.asarray(y_full),
            atol=1e-5,
        )
        np.testing.assert_allclose(np.asarray(s2), np.asarray(h_full), atol=1e-5)


class TestSegmentSum:
    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(4, 300),
        d=st.sampled_from([4, 16, 33]),
        s=st.integers(2, 20),
        seed=st.integers(0, 100),
        block=st.sampled_from([16, 64, 512]),
    )
    def test_matches_reference(self, n, d, s, seed, block):
        rng = np.random.default_rng(seed)
        values = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        ids = jnp.asarray(np.sort(rng.integers(0, s, size=n)).astype(np.int32))
        out = segment_sum(values, ids, s, block_n=block)
        expect = ref.segment_sum_ref(values, ids, s)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4)

    def test_unsorted_ids_still_correct(self):
        rng = np.random.default_rng(0)
        values = jnp.asarray(rng.normal(size=(128, 8)).astype(np.float32))
        ids = jnp.asarray(rng.integers(0, 7, size=128).astype(np.int32))
        out = segment_sum(values, ids, 7, block_n=32)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.segment_sum_ref(values, ids, 7)), atol=1e-4
        )


class TestMoEDispatch:
    @pytest.mark.parametrize("T,D,E,C", [(128, 32, 4, 40), (200, 64, 8, 16), (64, 16, 3, 64)])
    def test_matches_reference(self, T, D, E, C):
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        tokens = jax.random.normal(ks[0], (T, D))
        eids = jax.random.randint(ks[1], (T,), 0, E)
        slots = compute_slots(eids, E)
        out = moe_dispatch(tokens, eids, slots, E, C, block_t=48)
        expect = ref.moe_dispatch_ref(tokens, eids, slots, E, C)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)

    def test_capacity_overflow_drops(self):
        # all tokens to expert 0 with capacity 4: only first 4 survive
        tokens = jnp.arange(80, dtype=jnp.float32).reshape(8, 10)
        eids = jnp.zeros(8, jnp.int32)
        slots = compute_slots(eids, 2)
        out = moe_dispatch(tokens, eids, slots, 2, 4, block_t=8)
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(tokens[:4]))
        assert float(jnp.abs(out[1]).sum()) == 0.0

    def test_roundtrip_dispatch_combine(self):
        """dispatch → identity expert → combine reproduces gated tokens."""
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        T, D, E, C = 96, 16, 4, 32  # capacity ample: no drops
        tokens = jax.random.normal(ks[0], (T, D))
        eids = jax.random.randint(ks[1], (T,), 0, E)
        gates = jax.nn.sigmoid(jax.random.normal(ks[2], (T,)))
        buf, slots = ops.dispatch_tokens(tokens, eids, E, C)
        back = ops.combine_tokens(buf, eids, slots, gates, C)
        np.testing.assert_allclose(
            np.asarray(back), np.asarray(tokens * gates[:, None]), atol=1e-5
        )


class TestOpsFallback:
    def test_small_shapes_use_reference(self):
        """Tiny inputs route to the reference and still agree with it."""
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, 2, 8, 16))
        k = jax.random.normal(ks[1], (1, 2, 8, 16))
        v = jax.random.normal(ks[2], (1, 2, 8, 16))
        np.testing.assert_allclose(
            np.asarray(ops.attention(q, k, v)),
            np.asarray(ref.attention_ref(q, k, v)),
            atol=1e-6,
        )
