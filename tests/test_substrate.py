"""Substrate tests: optimizer, train step (accumulation/compression),
checkpoint manager (atomicity, retention, elastic restore), data pipeline
determinism, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data.pipeline import GeoDataPipeline, synthetic_lm_batch
from repro.core.platform import tpu_pod_platform
from repro.models import model as M
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.train.checkpoint import CheckpointManager
from repro.train.compression import ef_compress_tree
from repro.train.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.train.train_step import TrainConfig, init_state, make_train_step


@pytest.fixture(scope="module")
def small():
    cfg = ARCHS["qwen3-1.7b"].reduced()
    params = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _batch(cfg, step=0, B=4, T=32):
    return {
        k: jnp.asarray(v)
        for k, v in synthetic_lm_batch(cfg.vocab, B, T, step, seed=7).items()
    }


class TestOptim:
    def test_adamw_converges_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw_init(params)
        cfg = AdamWConfig(lr=0.3, weight_decay=0.0)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state, _ = adamw_update(cfg, params, g, state)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_grad_clip(self):
        params = {"w": jnp.zeros(3)}
        state = adamw_init(params)
        cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
        _, _, m = adamw_update(cfg, params, {"w": jnp.full(3, 100.0)}, state)
        assert float(m["grad_norm"]) > 100

    def test_cosine_schedule(self):
        lr = cosine_schedule(1.0, warmup_steps=10, total_steps=100)
        assert float(lr(jnp.int32(0))) == 0.0
        assert float(lr(jnp.int32(10))) == pytest.approx(1.0)
        assert float(lr(jnp.int32(100))) == pytest.approx(0.1, abs=1e-6)


class TestTrainStep:
    def test_loss_decreases(self, small):
        cfg, params = small
        tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-2), remat=False,
                           compute_dtype=jnp.float32)
        step = jax.jit(make_train_step(cfg, tcfg))
        state = init_state(cfg, params)
        batch = _batch(cfg)
        losses = []
        for _ in range(5):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0]
        assert int(state.step) == 5

    def test_microbatch_accumulation_matches_full(self, small):
        """grad-accumulated step == single-batch step (same data)."""
        cfg, params = small
        batch = _batch(cfg, B=8)
        outs = {}
        for k in (1, 4):
            tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-2), microbatches=k,
                               remat=False, compute_dtype=jnp.float32)
            step = jax.jit(make_train_step(cfg, tcfg))
            state, _ = step(init_state(cfg, params), batch)
            outs[k] = state.params
        diff = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), outs[1], outs[4]
        )
        assert max(jax.tree_util.tree_leaves(diff)) < 5e-3

    def test_compression_error_feedback(self, small):
        cfg, params = small
        tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-2), compression="int8",
                           remat=False, compute_dtype=jnp.float32)
        step = jax.jit(make_train_step(cfg, tcfg))
        state = init_state(cfg, params, compression="int8")
        l0 = None
        for i in range(6):
            state, metrics = step(state, _batch(cfg, step=0))
            l0 = l0 or float(metrics["loss"])
        assert float(metrics["loss"]) < l0  # still trains through int8
        # residual is live (error feedback active)
        res_norm = sum(
            float(jnp.abs(r).sum()) for r in jax.tree_util.tree_leaves(state.residual)
        )
        assert res_norm > 0

    def test_ef_compression_reconstruction_error_bounded(self):
        key = jax.random.PRNGKey(0)
        g = {"a": jax.random.normal(key, (64, 64))}
        r = {"a": jnp.zeros((64, 64))}
        rec, new_r = ef_compress_tree(g, r, key, kind="int8")
        rel = float(
            jnp.linalg.norm(rec["a"] - g["a"]) / jnp.linalg.norm(g["a"])
        )
        assert rel < 0.05
        np.testing.assert_allclose(
            np.asarray(rec["a"] + new_r["a"]), np.asarray(g["a"]), atol=1e-5
        )


class TestCheckpoint:
    def test_roundtrip_and_retention(self, small, tmp_path):
        cfg, params = small
        mgr = CheckpointManager(str(tmp_path), keep=2)
        state = init_state(cfg, params)
        for s in [1, 2, 3, 4]:
            mgr.save(s, state.params, extras={"step": s})
        assert mgr.steps() == [3, 4]  # retention
        like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                            state.params)
        restored, extras, step = mgr.restore(None, like)
        assert step == 4 and extras["step"] == 4
        for a, b in zip(jax.tree_util.tree_leaves(restored),
                        jax.tree_util.tree_leaves(state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_uncommitted_checkpoint_ignored(self, small, tmp_path):
        cfg, params = small
        mgr = CheckpointManager(str(tmp_path), keep=3)
        mgr.save(1, {"w": jnp.ones(3)})
        # simulate a crash: step 2 exists without the COMMITTED marker
        os.makedirs(tmp_path / "step_000000002" / "arrays")
        assert mgr.latest_step() == 1

    def test_async_save(self, small, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        mgr.save_async(7, {"w": jnp.arange(5.0)})
        mgr.wait()
        assert mgr.steps() == [7]

    def test_milestone_survives_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=1)
        mgr.save(1, {"w": jnp.ones(2)}, milestone=True)
        for s in [2, 3, 4]:
            mgr.save(s, {"w": jnp.ones(2)})
        assert 1 in mgr.steps() and 4 in mgr.steps()


class TestDataPipeline:
    def test_determinism_across_restart(self):
        p = tpu_pod_platform(n_pods=2, hosts_per_pod=2)
        pipe = GeoDataPipeline(p, vocab=100, batch=4, seq=16, seed=3)
        b5 = pipe.batch_at(5)
        pipe2 = GeoDataPipeline(p, vocab=100, batch=4, seq=16, seed=3)
        np.testing.assert_array_equal(b5["tokens"], pipe2.batch_at(5)["tokens"])

    def test_prefetch_thread(self):
        p = tpu_pod_platform(n_pods=2, hosts_per_pod=2)
        pipe = GeoDataPipeline(p, vocab=100, batch=2, seq=8, seed=0).start(from_step=3)
        try:
            s, b = next(pipe)
            assert s == 3 and b["tokens"].shape == (2, 8)
            s, _ = next(pipe)
            assert s == 4
        finally:
            pipe.stop()

    def test_plan_beats_myopic_ingest_when_heterogeneous(self):
        p = tpu_pod_platform(
            n_pods=2, hosts_per_pod=2, ingest_bw_mbps=3200.0, seed=0,
            compute_jitter=0.5,
        )
        pipe = GeoDataPipeline(p, vocab=100, batch=2, seq=8)
        assert pipe.modeled_ingest_time() > 0
        assert len(pipe.assignments) == p.nM
        for a in pipe.assignments:
            assert a.fractions.shape == (p.nS,)


class TestServeEngine:
    def test_continuous_batching_serves_all(self, small):
        cfg, params = small
        eng = ServeEngine(cfg, params, ServeConfig(slots=2, max_len=64))
        rng = np.random.default_rng(0)
        reqs = [
            Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=n).astype(np.int32),
                    max_new_tokens=4 + i)
            for i, n in enumerate([5, 9, 3, 7])
        ]
        for r in reqs:
            eng.submit(r)
        done = eng.run()
        assert len(done) == 4
        for r in reqs:
            assert r.done and len(r.output) == r.max_new_tokens
            assert r.ttft_steps is not None

    def test_engine_matches_sequential_decode(self, small):
        """Engine output for a single request == hand-rolled greedy decode."""
        cfg, params = small
        prompt = np.arange(1, 9, dtype=np.int32)
        eng = ServeEngine(cfg, params, ServeConfig(slots=2, max_len=64))
        req = Request(rid=0, prompt=prompt, max_new_tokens=5)
        eng.submit(req)
        eng.run()
        # reference: full forward re-run each step
        toks = list(prompt)
        out = []
        for _ in range(5):
            logits, _, _ = M.forward(
                cfg, params, {"tokens": jnp.asarray(np.asarray(toks)[None])}
            )
            nxt = int(np.argmax(np.asarray(logits[0, -1])))
            out.append(nxt)
            toks.append(nxt)
        assert req.output == out
