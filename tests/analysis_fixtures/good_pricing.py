"""f64-pricing-purity: GOOD — the pricing call graph stays numpy-pinned
float64 end to end."""
import numpy as np


def _helper(v, xp=np):
    return xp.cumsum(v)


def volume_model(v):
    ends = _helper(v, xp=np)
    return float(np.max(ends))
