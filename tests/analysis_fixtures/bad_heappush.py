"""no-bare-heappush: BAD — an event is pushed outside ``at()``, bypassing
the single home of the (time, seq) tie-break discipline."""
import heapq


def schedule(heap, t, fn):
    heapq.heappush(heap, (t, fn))
