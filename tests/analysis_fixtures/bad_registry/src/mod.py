"""registry-coverage: BAD — a mode is registered but never referenced in
the project's tests or README."""


def register_planner(name, fn=None):
    return fn


def _ghost(platform):
    return None


register_planner("ghost_mode", _ghost)
