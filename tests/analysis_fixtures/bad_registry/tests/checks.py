"""Exercises the module without ever naming the registered mode."""
