"""solver-compile-counters: GOOD — every ``_solve*`` kernel goes through
``_counted_solver`` (which wraps ``jax.jit`` and maintains the shape-keyed
hit/miss/compile counters); helper names that merely start with ``solve``
or live inside a class are out of scope."""


def _counted_solver(static_argnames=()):
    def deco(fn):
        return fn
    return deco


@_counted_solver(static_argnames=("steps",))
def _solve_batch(arrs, logits, steps):
    return arrs, logits


def solve_helper(x):
    return x
