"""as-dict-json: BAD — sets, bytes and a raw ndarray inside ``as_dict()``
would all blow up (or silently mangle) in ``json.dump``."""
import numpy as np


class Report:
    def __init__(self, ends):
        self.ends = ends

    def as_dict(self):
        return {
            "ends": np.asarray(self.ends),
            "tags": {"a", "b"},
            "blob": b"raw",
        }
