"""solver-compile-counters: BAD — a ``_solve*`` kernel jitted directly,
bypassing the shape-keyed cache counters."""
import jax


@jax.jit
def _solve_batch(arrs, logits):
    return arrs, logits
