"""as-dict-json: GOOD — every value is coerced to a JSON-native form."""
import numpy as np


class Report:
    def __init__(self, ends):
        self.ends = ends

    def as_dict(self):
        return {
            "ends": np.asarray(self.ends).tolist(),
            "total": float(np.asarray(self.ends).sum()),
            "tags": ["a", "b"],
        }
