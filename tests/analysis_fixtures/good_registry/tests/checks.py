"""References the registered mode: ghost_mode."""
