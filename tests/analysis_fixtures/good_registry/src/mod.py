"""registry-coverage: GOOD — the registered mode is referenced in both the
tests and the README."""


def register_planner(name, fn=None):
    return fn


def _ghost(platform):
    return None


register_planner("ghost_mode", _ghost)
