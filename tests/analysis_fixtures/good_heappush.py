"""no-bare-heappush: GOOD — the only insertion lives inside ``at()``."""
import heapq
import itertools


class Engine:
    def __init__(self):
        self.heap = []
        self._seq = itertools.count()

    def at(self, t, fn, *args):
        heapq.heappush(self.heap, (t, next(self._seq), fn, args))
