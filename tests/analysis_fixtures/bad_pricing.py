"""f64-pricing-purity: BAD — jnp leaks into the pricing call graph and an
xp-parameterized helper is called without pinning xp=np."""
import jax.numpy as jnp


def _helper(v, xp=jnp):
    return xp.cumsum(v)


def volume_model(v):
    ends = _helper(v)  # missing xp=np pin
    return jnp.max(ends)  # jnp in a pricing-reachable function
