"""no-bare-heappush: WAIVED — the inline comment suppresses the finding."""
import heapq


def replay(heap, ev):
    heapq.heappush(heap, ev)  # lint: ignore[no-bare-heappush]
