"""Tests for multi-stage pipelines (PR 5): the stage-DAG plan layer,
cross-stage cost-model pricing, stagewise-vs-end-to-end planning, the
executor's inter-stage release gating, the GeoPipeline facade (alone and
inside GeoSchedule / run_online), and the replication-pricing fix."""
import itertools
import json

import numpy as np
import pytest

from repro.api import GeoJob, GeoPipeline, GeoSchedule
from repro.core.makespan import (
    BARRIERS_GGL,
    CostModel,
    JobProgress,
    replication_matrix,
)
from repro.core.optimize import (
    available_pipeline_modes,
    optimize_pipeline,
    optimize_plan,
    register_pipeline_planner,
)
from repro.core.pipeline import PipelineSpec, StageSpec, chain_spec
from repro.core.plan import ExecutionPlan, uniform_plan
from repro.core.platform import (
    Substrate,
    planetlab_platform,
    two_cluster_example,
)
from repro.core.simulate import SimConfig, open_schedule, simulate, simulate_schedule

ALL_BARRIER_TRIPLES = list(itertools.product("GLP", repeat=3))

OPT = dict(n_restarts=6, steps=150)


def chain_substrate() -> Substrate:
    """Asymmetric outgoing access: node 0 hosts the fast reducer but its
    outgoing push links crawl — the stagewise trap."""
    return Substrate(
        B_sm=np.array([[4.0, 4.0], [200.0, 200.0]]),
        B_mr=np.full((2, 2), 200.0),
        C_m=np.array([100.0, 100.0]),
        C_r=np.array([300.0, 60.0]),
        cluster_s=np.array([0, 1]),
        cluster_m=np.array([0, 1]),
        cluster_r=np.array([0, 1]),
        name="chain_pair",
    )


def chain_stages(sub: Substrate):
    return [
        GeoJob(sub.view(np.array([0.0, 6000.0]), 1.0, name="ingest")),
        GeoJob(sub.view(np.zeros(2), 1.0, name="transform")),
        GeoJob(sub.view(np.zeros(2), 0.5, name="aggregate")),
    ]


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------


class TestSpecValidation:
    def test_cycle_rejected(self):
        sub = chain_substrate()
        a = StageSpec(sub.view(np.full(2, 10.0), 1.0), deps=(1,))
        b = StageSpec(sub.view(np.zeros(2), 1.0), deps=(0,))
        with pytest.raises(ValueError, match="cycle"):
            PipelineSpec(stages=(a, b))

    def test_self_dep_rejected(self):
        sub = chain_substrate()
        with pytest.raises(ValueError, match="itself"):
            PipelineSpec(stages=(
                StageSpec(sub.view(np.full(2, 10.0), 1.0), deps=(0,)),
            ))

    def test_unknown_dep_rejected(self):
        sub = chain_substrate()
        with pytest.raises(ValueError, match="unknown stage"):
            PipelineSpec(stages=(
                StageSpec(sub.view(np.full(2, 10.0), 1.0), deps=(3,)),
            ))

    def test_duplicate_deps_rejected(self):
        sub = chain_substrate()
        with pytest.raises(ValueError, match="duplicate"):
            StageSpec(sub.view(np.full(2, 10.0), 1.0), deps=(0, 0))

    def test_negative_out_scale_rejected(self):
        sub = chain_substrate()
        with pytest.raises(ValueError, match="out_scale"):
            StageSpec(sub.view(np.full(2, 10.0), 1.0), out_scale=-0.5)

    def test_dependent_stage_needs_square_substrate(self):
        sub = Substrate(
            B_sm=np.full((2, 2), 100.0),
            B_mr=np.full((2, 3), 100.0),  # nR=3 != nS=2
            C_m=np.full(2, 100.0),
            C_r=np.full(3, 100.0),
            cluster_s=np.zeros(2, dtype=int),
            cluster_m=np.zeros(2, dtype=int),
            cluster_r=np.zeros(3, dtype=int),
        )
        root = StageSpec(sub.view(np.full(2, 10.0), 1.0))
        child = StageSpec(sub.view(np.zeros(2), 1.0), deps=(0,))
        with pytest.raises(ValueError, match="nS"):
            PipelineSpec(stages=(root, child))

    def test_substrate_mismatch_rejected(self):
        a = chain_substrate()
        b = two_cluster_example()
        with pytest.raises(ValueError, match="substrate"):
            PipelineSpec(stages=(
                StageSpec(a.view(np.full(2, 10.0), 1.0)),
                StageSpec(b, deps=(0,)),
            ))

    def test_geopipeline_cyclic_edges_rejected(self):
        sub = chain_substrate()
        stages = [GeoJob(sub.view(np.full(2, 10.0), 1.0)),
                  GeoJob(sub.view(np.zeros(2), 1.0))]
        with pytest.raises(ValueError, match="cycle"):
            GeoPipeline(stages, edges=[(0, 1), (1, 0)])

    def test_topo_and_sinks(self):
        sub = chain_substrate()
        # diamond: 0 -> {1, 2} -> 3
        spec = PipelineSpec(stages=(
            StageSpec(sub.view(np.full(2, 10.0), 1.0)),
            StageSpec(sub.view(np.zeros(2), 1.0), deps=(0,)),
            StageSpec(sub.view(np.zeros(2), 1.0), deps=(0,)),
            StageSpec(sub.view(np.zeros(2), 1.0), deps=(1, 2)),
        ))
        order = spec.topo_order()
        assert order.index(0) < order.index(1) < order.index(3)
        assert order.index(0) < order.index(2) < order.index(3)
        assert spec.sinks() == (3,)
        assert spec.children()[0] == (1, 2)


# ---------------------------------------------------------------------------
# derived D + pricing
# ---------------------------------------------------------------------------


class TestDerivedD:
    def test_chain_derivation_by_hand(self):
        sub = chain_substrate()
        spec = chain_spec(
            [sub.view(np.array([0.0, 6000.0]), 2.0),
             sub.view(np.zeros(2), 1.0)],
            out_scales=[0.5, 1.0],
        )
        y0 = np.array([0.25, 0.75])
        plans = [
            ExecutionPlan(x=uniform_plan(sub.view(np.zeros(2), 1.0)).x,
                          y=y0),
            uniform_plan(sub.view(np.zeros(2), 1.0)),
        ]
        D = spec.derived_D(plans)
        # stage 1 source s gets out_scale0 * alpha0 * total0 * y0[s]
        np.testing.assert_allclose(D[1], 0.5 * 2.0 * 6000.0 * y0)
        np.testing.assert_allclose(D[0], [0.0, 6000.0])

    def test_diamond_accumulates_both_parents(self):
        sub = chain_substrate()
        spec = PipelineSpec(stages=(
            StageSpec(sub.view(np.array([100.0, 100.0]), 1.0)),
            StageSpec(sub.view(np.zeros(2), 1.0), deps=(0,), out_scale=1.0),
            StageSpec(sub.view(np.zeros(2), 2.0), deps=(0,), out_scale=1.0),
            StageSpec(sub.view(np.zeros(2), 1.0), deps=(1, 2)),
        ))
        plans = [uniform_plan(sub.view(np.zeros(2), 1.0)) for _ in range(4)]
        D = spec.derived_D(plans)
        # stage 3 gets stage 1's output (200 MB) + stage 2's (alpha=2: 400)
        np.testing.assert_allclose(D[3].sum(), 200.0 + 400.0)

    def test_single_root_price_pipeline_equals_price_plan(self):
        p = planetlab_platform(8, alpha=1.0, seed=0)
        spec = chain_spec([p])
        plan = uniform_plan(p)
        cm = CostModel(p, BARRIERS_GGL)
        out = cm.price_pipeline(spec, [plan])
        assert out["makespan"] == cm.makespan(plan)
        assert out["start"] == [0.0]

    def test_composition_is_critical_path(self):
        sub = chain_substrate()
        spec = chain_spec([
            sub.view(np.array([0.0, 6000.0]), 1.0),
            sub.view(np.zeros(2), 1.0),
        ])
        plans = [uniform_plan(sub.view(np.zeros(2), 1.0)) for _ in range(2)]
        cm = CostModel(sub.view(np.zeros(2), 1.0), BARRIERS_GGL)
        out = cm.price_pipeline(spec, plans)
        s0 = float(out["stages"][0]["makespan"])
        s1 = float(out["stages"][1]["makespan"])
        assert out["start"][1] == pytest.approx(s0)
        assert out["makespan"] == pytest.approx(s0 + s1)


# ---------------------------------------------------------------------------
# planners
# ---------------------------------------------------------------------------


class TestPipelinePlanners:
    def test_registry(self):
        assert "stagewise" in available_pipeline_modes()
        assert "end_to_end" in available_pipeline_modes()
        with pytest.raises(ValueError, match="pipeline mode"):
            optimize_pipeline(
                chain_spec([planetlab_platform(4, seed=0)]), mode="nope"
            )
        with pytest.raises(ValueError, match="already registered"):
            register_pipeline_planner(
                "stagewise", lambda *a, **k: None
            )

    def test_single_stage_stagewise_matches_optimize_plan(self):
        p = planetlab_platform(4, alpha=1.0, seed=0)
        res = optimize_pipeline(
            chain_spec([p]), mode="stagewise", barriers=BARRIERS_GGL, **OPT
        )
        solo = optimize_plan(p, "e2e_multi", barriers=BARRIERS_GGL, **OPT)
        np.testing.assert_array_equal(res.plans[0].x, solo.plan.x)
        np.testing.assert_array_equal(res.plans[0].y, solo.plan.y)
        assert res.makespan == pytest.approx(solo.makespan, abs=1e-9)

    def test_end_to_end_never_modeled_worse_than_stagewise(self):
        sub = chain_substrate()
        for seed in (0, 1, 2):
            spec = chain_spec([
                sub.view(np.array([0.0, 6000.0]), 1.0),
                sub.view(np.zeros(2), 1.0),
                sub.view(np.zeros(2), 0.5),
            ])
            sw = optimize_pipeline(spec, "stagewise",
                                   barriers=BARRIERS_GGL, seed=seed, **OPT)
            e2e = optimize_pipeline(spec, "end_to_end",
                                    barriers=BARRIERS_GGL, seed=seed, **OPT)
            assert e2e.makespan <= sw.makespan + 1e-9

    def test_end_to_end_beats_stagewise_on_chain_scenario(self):
        """The acceptance scenario: >= 20% simulated reduction (modeled and
        simulated both gated)."""
        sub = chain_substrate()
        sims = {}
        for mode in ("stagewise", "end_to_end"):
            report = (
                GeoPipeline(chain_stages(sub), name=mode)
                .plan(mode, barriers=BARRIERS_GGL, **OPT)
                .simulate()
            )
            sims[mode] = report
        assert (sims["end_to_end"].makespan_modeled
                <= sims["stagewise"].makespan_modeled + 1e-9)
        assert (1 - sims["end_to_end"].makespan_sim
                / sims["stagewise"].makespan_sim) >= 0.20
        assert (1 - sims["end_to_end"].makespan_modeled
                / sims["stagewise"].makespan_modeled) >= 0.20

    def test_result_repr_and_fields(self):
        sub = chain_substrate()
        spec = chain_spec([sub.view(np.array([0.0, 1000.0]), 1.0),
                           sub.view(np.zeros(2), 1.0)])
        res = optimize_pipeline(spec, "stagewise", barriers=BARRIERS_GGL,
                                **OPT)
        assert len(res.plans) == 2
        assert res.finishes[1] == pytest.approx(res.makespan)
        assert "PipelinePlanResult" in repr(res)
        assert res.stage_D[1].sum() == pytest.approx(1000.0)


# ---------------------------------------------------------------------------
# executor: inter-stage release gating
# ---------------------------------------------------------------------------


class TestPipelineExecution:
    def test_single_stage_pipeline_is_simulate_exactly(self):
        """A one-stage pipeline must reproduce simulate() <= 1e-9 per
        phase, for every barrier triple."""
        sub = chain_substrate()
        p = sub.view(np.array([3000.0, 3000.0]), 1.0, name="solo")
        plan = uniform_plan(p)
        for barriers in ALL_BARRIER_TRIPLES:
            cfg = SimConfig(barriers=barriers)
            solo = simulate(p, plan, cfg)
            job = GeoJob(p).with_plan(plan, barriers)
            rep = GeoPipeline([job]).with_plans().simulate(cfg)
            a, b = solo.phases(), rep.sims[0].phases()
            for phase in a:
                assert abs(a[phase] - b[phase]) <= 1e-9, (barriers, phase)
            assert abs(rep.makespan_sim - solo.makespan) <= 1e-9

    def test_downstream_waits_for_upstream_reducer(self):
        """With all of stage 1's output on reducer 0, stage 2's push links
        out of node 0 must stay idle until stage 1 fully completes."""
        sub = chain_substrate()
        p0 = sub.view(np.array([0.0, 4000.0]), 1.0)
        plan0 = ExecutionPlan(
            x=np.array([[0.5, 0.5], [0.5, 0.5]]), y=np.array([1.0, 0.0])
        )
        p1 = sub.view(np.array([4000.0, 0.0]), 1.0)
        plan1 = uniform_plan(p1)
        cfg = SimConfig(barriers=BARRIERS_GGL)
        sim = simulate_schedule(
            [(p0, plan0, cfg), (p1, plan1, cfg)],
            substrate=sub, stage_links={1: [(0, 1.0)]},
        )
        stage1, stage2 = sim.jobs
        for j in range(2):
            stats = sim.resources[f"push[s0->m{j}]"]
            if stats.n_chunks:
                assert stats.first_busy_s >= stage1.reduce_end - 1e-9
        assert stage2.reduce_end > stage1.reduce_end

    def test_measured_volume_flows_downstream(self):
        """Stage 2 pushes exactly out_scale x alpha x stage-1 input."""
        sub = chain_substrate()
        p0 = sub.view(np.array([0.0, 4000.0]), 2.0)
        p1 = sub.view(np.array([0.0, 0.0]), 1.0)
        plan = uniform_plan(p0)
        cfg = SimConfig(barriers=BARRIERS_GGL)
        sim = simulate_schedule(
            [(p0, plan, cfg), (p1, plan, cfg)],
            substrate=sub, stage_links={1: [(0, 0.5)]},
        )
        pushed = sum(
            sim.resources[f"push[s{i}->m{j}]"].volume_mb
            for i in range(2) for j in range(2)
        )
        # stage1 pushes 4000; stage2 pushes 0.5 * 2.0 * 4000 = 4000
        assert pushed == pytest.approx(8000.0, rel=1e-6)

    def test_zero_out_scale_child_completes_empty(self):
        sub = chain_substrate()
        p0 = sub.view(np.array([0.0, 1000.0]), 1.0)
        p1 = sub.view(np.zeros(2), 1.0)
        plan = uniform_plan(p0)
        cfg = SimConfig(barriers=BARRIERS_GGL)
        sim = simulate_schedule(
            [(p0, plan, cfg), (p1, plan, cfg)],
            substrate=sub, stage_links={1: [(0, 0.0)]},
        )
        assert sim.jobs[1].makespan == 0.0
        assert sim.makespan == pytest.approx(sim.jobs[0].makespan)

    def test_chain_completes_under_every_barrier_triple(self):
        sub = chain_substrate()
        p0 = sub.view(np.array([0.0, 2000.0]), 1.0)
        p1 = sub.view(np.zeros(2), 1.0)
        plan = uniform_plan(p0)
        for barriers in ALL_BARRIER_TRIPLES:
            cfg = SimConfig(barriers=barriers)
            sim = simulate_schedule(
                [(p0, plan, cfg), (p1, plan, cfg)],
                substrate=sub, stage_links={1: [(0, 1.0)]},
            )
            assert sim.jobs[1].reduce_end >= sim.jobs[0].reduce_end
            assert sim.makespan == pytest.approx(sim.jobs[1].reduce_end)

    def test_three_stage_diamond_executes(self):
        sub = chain_substrate()
        root = sub.view(np.array([0.0, 2000.0]), 1.0)
        mid = sub.view(np.zeros(2), 1.0)
        plan = uniform_plan(root)
        cfg = SimConfig(barriers=BARRIERS_GGL)
        sim = simulate_schedule(
            [(root, plan, cfg), (mid, plan, cfg), (mid, plan, cfg),
             (mid, plan, cfg)],
            substrate=sub,
            stage_links={1: [(0, 1.0)], 2: [(0, 1.0)], 3: [(1, 1.0),
                                                           (2, 1.0)]},
        )
        assert sim.jobs[3].reduce_end >= max(sim.jobs[1].reduce_end,
                                             sim.jobs[2].reduce_end)

    def test_link_stages_validation(self):
        sub = chain_substrate()
        p = sub.view(np.array([100.0, 100.0]), 1.0)
        plan = uniform_plan(p)
        cfg = SimConfig(barriers=BARRIERS_GGL)
        with pytest.raises(ValueError, match="cycle"):
            open_schedule(
                [(p, plan, cfg), (p, plan, cfg)], substrate=sub,
                stage_links={1: [(0, 1.0)], 0: [(1, 1.0)]},
            )
        with pytest.raises(ValueError, match="bad parent"):
            open_schedule(
                [(p, plan, cfg)], substrate=sub, stage_links={0: [(5, 1.0)]},
            )
        eng = open_schedule(
            [(p, plan, cfg), (p, plan, cfg)], substrate=sub,
            stage_links={1: [(0, 1.0)]},
        )
        eng.run_until(0.0)
        with pytest.raises(RuntimeError, match="precede"):
            eng.link_stages(0, [(1, 1.0)])

    def test_snapshot_exposes_pending_stage_volume(self):
        """An unreleased downstream stage's modeled D shows up as
        re-routable push residual — what run_online steers."""
        sub = chain_substrate()
        p0 = sub.view(np.array([0.0, 4000.0]), 1.0)
        p1 = sub.view(np.array([2000.0, 2000.0]), 1.0)  # derived/modeled D
        plan = uniform_plan(p0)
        cfg = SimConfig(barriers=BARRIERS_GGL)
        eng = open_schedule(
            [(p0, plan, cfg), (p1, plan, cfg)],
            substrate=sub, stage_links={1: [(0, 1.0)]},
        )
        eng.run_until(5.0)
        snap = eng.snapshot()
        child = snap.jobs[1]
        assert not child.done
        assert child.resid_push.sum() == pytest.approx(4000.0)
        # swapping the unreleased stage's plan steers its future seeding
        eng.swap_plan(1, ExecutionPlan(
            x=np.array([[0.0, 1.0], [0.0, 1.0]]), y=np.array([0.0, 1.0])
        ))
        sim = eng.run()
        # the swapped x routes everything to m1: s0's link to m0 never used
        assert sim.resources["push[s0->m0]"].n_chunks == 0
        assert sim.resources["push[s0->m1]"].n_chunks > 0

    def test_swap_never_routes_shuffle_onto_finalized_reducer(self):
        """Once a parent reducer's output has been handed to the
        downstream stage, a plan swap must not re-route still-queued
        shuffle volume onto it — that delivery window is closed, and the
        data must reach the child through the still-open reducers."""
        sub = Substrate(
            B_sm=np.full((2, 2), 200.0),
            # shuffle into r1 crawls, so its chunks queue (re-routable)
            # long after the fast r0 has drained and finalized
            B_mr=np.array([[500.0, 5.0], [500.0, 5.0]]),
            C_m=np.array([100.0, 100.0]),
            C_r=np.array([2000.0, 2000.0]),
            cluster_s=np.array([0, 1]),
            cluster_m=np.array([0, 1]),
            cluster_r=np.array([0, 1]),
            name="late_swap",
        )
        p0 = sub.view(np.array([0.0, 2000.0]), 1.0)
        p1 = sub.view(np.array([1000.0, 1000.0]), 1.0)
        plan0 = ExecutionPlan(
            x=np.array([[0.5, 0.5], [0.5, 0.5]]), y=np.array([0.5, 0.5])
        )
        cfg = SimConfig(barriers=BARRIERS_GGL)
        eng = open_schedule(
            [(p0, plan0, cfg), (p1, uniform_plan(p1), cfg)],
            substrate=sub, stage_links={1: [(0, 1.0)]},
        )
        # by t=40 the parent's r0 side is reduced and finalized (child
        # source 0 released) while r1-bound chunks still sit queued
        eng.run_until(40.0)
        parent = eng.runs[0]
        assert parent.reducer_final[0] and not parent.reducer_final[1]
        snap = eng.snapshot()
        assert snap.jobs[0].shuffle_pool.sum() > 0  # re-routable volume
        # swap the parent's y entirely onto the finalized r0
        eng.swap_plan(0, ExecutionPlan(x=plan0.x, y=np.array([1.0, 0.0])))
        sim = eng.run()
        # conservation: the child still receives the parent's full output
        # (2000 MB parent push + 2000 MB child push over all push links)
        pushed = sum(
            sim.resources[f"push[s{i}->m{j}]"].volume_mb
            for i in range(2) for j in range(2)
        )
        assert pushed == pytest.approx(4000.0, rel=1e-6)


# ---------------------------------------------------------------------------
# facade
# ---------------------------------------------------------------------------


class TestGeoPipelineFacade:
    def test_plan_adopts_stage_jobs(self):
        sub = chain_substrate()
        stages = chain_stages(sub)
        pipe = GeoPipeline(stages, name="c").plan(
            "stagewise", barriers=BARRIERS_GGL, **OPT
        )
        for k, job in enumerate(stages):
            assert job.planned.plan is pipe.planned.plans[k]
        # derived D adopted into the stage platforms
        assert stages[1].platform.D.sum() == pytest.approx(6000.0)
        assert stages[2].platform.D.sum() == pytest.approx(6000.0)

    def test_unplanned_raises(self):
        pipe = GeoPipeline(chain_stages(chain_substrate()))
        with pytest.raises(RuntimeError, match="no plan"):
            pipe.planned

    def test_report_as_dict_roundtrips(self):
        sub = chain_substrate()
        rep = (
            GeoPipeline(chain_stages(sub))
            .plan("stagewise", barriers=BARRIERS_GGL, **OPT)
            .simulate()
        )
        doc = rep.as_dict()
        again = json.loads(json.dumps(doc))
        assert again == doc
        assert again["makespan"] == pytest.approx(rep.makespan_modeled)
        assert again["simulated"]["makespan"] == pytest.approx(
            rep.makespan_sim
        )
        assert len(again["stages"]) == 3

    def test_out_scales_mismatch_rejected(self):
        sub = chain_substrate()
        with pytest.raises(ValueError, match="out_scale"):
            GeoPipeline(chain_stages(sub), out_scales=[1.0])

    def test_execute_chains_real_records(self):
        from repro.mapreduce.apps import generate_documents, word_count
        from repro.api import split_sources

        p = planetlab_platform(4, alpha=1.0, seed=0)
        sub = Substrate.of(p)
        keys, vals = generate_documents(200, 30, seed=7)
        srcs = split_sources(keys, vals, p.nS)
        stages = [
            GeoJob(sub.view(p.D, 1.0), word_count()),
            GeoJob(sub.view(np.zeros(p.nS), 1.0), word_count()),
        ]
        rep = (
            GeoPipeline(stages, name="wc")
            .plan("stagewise", barriers=BARRIERS_GGL, **OPT)
            .execute(srcs)
        )
        assert rep.jobs is not None and len(rep.jobs) == 2
        assert rep.makespan_measured > 0
        # stage 2 consumed stage 1's reducer outputs
        assert rep.jobs[1].stats.volumes_mb()[0].sum() > 0
        doc = json.loads(json.dumps(rep.as_dict()))
        assert doc["measured"]["makespan"] == pytest.approx(
            rep.makespan_measured
        )

    def test_schedule_with_pipeline_and_plain_job(self):
        sub = chain_substrate()
        pipe = GeoPipeline([
            GeoJob(sub.view(np.array([0.0, 4000.0]), 1.0)),
            GeoJob(sub.view(np.zeros(2), 1.0)),
        ], name="p")
        plain = GeoJob(sub.view(np.array([0.0, 1000.0]), 1.0, name="q"))
        sched = GeoSchedule([pipe, plain]).plan(
            policy="independent", barriers=BARRIERS_GGL, **OPT
        )
        assert len(sched.jobs) == 3  # two stages + the plain job
        report = sched.simulate()
        assert len(report.sims) == 3
        # the pipeline's stage 2 cannot finish before stage 1
        assert report.sims[1].reduce_end >= report.sims[0].reduce_end
        # schedule execute() with pipelines is explicitly unsupported
        with pytest.raises(RuntimeError, match="GeoPipeline.execute"):
            sched.execute([[], [], []])

    def test_run_online_static_reproduces_frozen_pipeline(self):
        sub = chain_substrate()
        pipe = GeoPipeline([
            GeoJob(sub.view(np.array([0.0, 4000.0]), 1.0)),
            GeoJob(sub.view(np.zeros(2), 1.0)),
        ], name="p")
        sched = GeoSchedule([pipe]).plan(
            policy="independent", barriers=BARRIERS_GGL, **OPT
        )
        frozen = sched.simulate()
        rep = sched.run_online(policy="static",
                               cfg=SimConfig(barriers=BARRIERS_GGL))
        assert rep.makespan_online == pytest.approx(
            frozen.makespan_sim, abs=1e-9
        )
        assert rep.makespan_static == pytest.approx(
            frozen.makespan_sim, abs=1e-9
        )


# ---------------------------------------------------------------------------
# replication pricing (the satellite fix)
# ---------------------------------------------------------------------------


class TestReplicationPricing:
    def test_matrix_identity_for_replication_one(self):
        assert replication_matrix(np.array([0, 0, 1, 1]), 1) is None

    def test_matrix_conserves_copies(self):
        for cross in (False, True):
            for r in (2, 3):
                R = replication_matrix(np.array([0, 0, 1, 1]), r, cross)
                np.testing.assert_allclose(R.sum(axis=1), float(r))

    def test_same_cluster_targets(self):
        # clusters {0,1} and {2,3}: j=0 replicates to its partner 1
        R = replication_matrix(np.array([0, 0, 1, 1]), 2,
                               cross_cluster=False)
        assert R[0, 1] == 1.0 and R[0, 2] == 0.0 and R[0, 0] == 1.0

    def test_cross_cluster_targets(self):
        R = replication_matrix(np.array([0, 0, 1, 1]), 2,
                               cross_cluster=True)
        assert R[0, 0] == 1.0
        assert R[0, 2] + R[0, 3] == 1.0 and R[0, 1] == 0.0

    def test_invalid_replication_rejected(self):
        p = planetlab_platform(4, seed=0)
        with pytest.raises(ValueError, match="replication"):
            CostModel(p, BARRIERS_GGL, replication=0)

    @pytest.mark.parametrize("replication,cross", [
        (1, False), (2, False), (2, True), (3, True),
    ])
    def test_model_push_matches_simulation(self, replication, cross):
        """The regression the fix is for: modeled vs discrete-event push
        time must agree once replica writes are priced (they were silently
        unpriced before)."""
        p = planetlab_platform(4, alpha=1.0, seed=0)
        plan = uniform_plan(p)
        cm = CostModel(p, BARRIERS_GGL, replication=replication,
                       cross_cluster_replication=cross)
        modeled = float(cm.price_plan(plan)["push_time"])
        sim = simulate(
            p, plan,
            SimConfig(barriers=BARRIERS_GGL, replication=replication,
                      cross_cluster_replication=cross),
        )
        assert sim.push_end == pytest.approx(modeled, rel=1e-6)

    def test_model_makespan_tracks_simulation_with_replication(self):
        """End-to-end: with the replication term the model's full makespan
        stays in lockstep with the executor (G push barrier: replicas only
        stretch the push phase)."""
        p = planetlab_platform(8, alpha=1.0, seed=0)
        plan = uniform_plan(p)
        for r in (2, 3):
            cm = CostModel(p, BARRIERS_GGL, replication=r,
                           cross_cluster_replication=True)
            sim = simulate(
                p, plan,
                SimConfig(barriers=BARRIERS_GGL, replication=r,
                          cross_cluster_replication=True),
            )
            assert sim.makespan == pytest.approx(cm.makespan(plan),
                                                 rel=1e-6)

    def test_unpriced_replication_was_wrong(self):
        """Sanity that the fix matters: the replication-blind model
        underprices the simulated push substantially."""
        p = planetlab_platform(4, alpha=1.0, seed=0)
        plan = uniform_plan(p)
        blind = float(CostModel(p, BARRIERS_GGL).price_plan(plan)["push_time"])
        sim = simulate(
            p, plan, SimConfig(barriers=BARRIERS_GGL, replication=3,
                               cross_cluster_replication=True),
        )
        assert sim.push_end > 1.5 * blind

    def test_fresh_residual_reproduces_price_plan_with_replication(self):
        p = planetlab_platform(4, alpha=1.0, seed=0)
        plan = uniform_plan(p)
        cm = CostModel(p, BARRIERS_GGL, replication=2)
        fresh = JobProgress.fresh(p)
        a = cm.price_plan(plan)
        b = cm.price_residual(fresh, plan)
        assert float(a["makespan"]) == pytest.approx(
            float(b["makespan"]), abs=1e-9
        )

    def test_shared_pricing_inflates_push(self):
        p = planetlab_platform(4, alpha=1.0, seed=0)
        plan = uniform_plan(p)
        base = CostModel(p, BARRIERS_GGL)
        repd = CostModel(p, BARRIERS_GGL, replication=2)
        vols = [base.analytic_volumes(plan)]
        plain = base.price_shared(
            [(p.D[:, None] * plan.x, *vols[0][1:])]
        )[0]
        inflated = repd.price_shared(
            [(p.D[:, None] * plan.x, *vols[0][1:])]
        )[0]
        assert float(inflated["push_time"]) > float(plain["push_time"])
