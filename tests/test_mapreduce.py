"""MapReduce engine tests: correctness of the three applications against
plain-python references, plan enforcement, byte accounting, and the
plan-quality ordering on the emulated PlanetLab platform."""
import numpy as np
import pytest

from repro.core.optimize import optimize_plan
from repro.core.plan import ExecutionPlan, local_push_plan, uniform_plan
from repro.core.platform import planetlab_platform, two_cluster_example
from repro.mapreduce.apps import (
    generate_documents,
    generate_logs,
    inverted_index,
    sessionization,
    synthetic_alpha_job,
    word_count,
)
from repro.mapreduce.engine import GeoMapReduce, MRApp
from repro.mapreduce.partition import bucket_owners, hash_keys


@pytest.fixture(scope="module")
def platform():
    return planetlab_platform(8, alpha=1.0, seed=0)


def _split_sources(keys, values, n):
    ks = np.array_split(keys, n)
    vs = np.array_split(values, n)
    return list(zip(ks, vs))


class TestPartition:
    def test_bucket_owners_proportional(self):
        y = np.array([0.5, 0.25, 0.25])
        owners = bucket_owners(y, 400)
        counts = np.bincount(owners, minlength=3)
        assert counts.tolist() == [200, 100, 100]

    def test_hash_deterministic_and_spread(self):
        keys = np.arange(10_000, dtype=np.int64)
        b1 = hash_keys(keys, 64)
        b2 = hash_keys(keys, 64)
        np.testing.assert_array_equal(b1, b2)
        counts = np.bincount(b1, minlength=64)
        assert counts.min() > 0.5 * counts.mean()


class TestWordCount:
    def test_counts_exact(self, platform):
        keys, vals = generate_documents(200, 50, seed=1)
        app = word_count()
        eng = GeoMapReduce(platform, uniform_plan(platform), app)
        outs, stats = eng.run(_split_sources(keys, vals, platform.nS))
        got = {}
        for k, v in outs:
            for kk, vv in zip(k, v):
                got[int(kk)] = got.get(int(kk), 0) + int(vv)
        words = (vals & ((1 << 20) - 1)).astype(np.int64)
        expect = {int(w): int(c) for w, c in zip(*np.unique(words, return_counts=True))}
        assert got == expect

    def test_word_count_aggregates(self, platform):
        keys, vals = generate_documents(200, 50, seed=1)
        app = word_count()
        eng = GeoMapReduce(platform, uniform_plan(platform), app)
        _, stats = eng.run(_split_sources(keys, vals, platform.nS))
        # heavy aggregation: far fewer intermediate records than inputs
        assert stats.alpha_measured < 0.7

    def test_one_reducer_per_key(self, platform):
        """No word may appear in two reducers' outputs (Equation 3)."""
        keys, vals = generate_documents(100, 40, seed=2)
        eng = GeoMapReduce(platform, uniform_plan(platform), word_count())
        outs, _ = eng.run(_split_sources(keys, vals, platform.nS))
        seen = {}
        for r, (k, _) in enumerate(outs):
            for kk in np.unique(k):
                assert kk not in seen, (kk, seen.get(kk), r)
                seen[int(kk)] = r


class TestSessionization:
    def test_sessions_match_reference(self, platform):
        users, vals = generate_logs(5000, n_users=50, seed=3)
        eng = GeoMapReduce(platform, uniform_plan(platform), sessionization(gap=1000))
        outs, stats = eng.run(_split_sources(users, vals, platform.nS))
        assert stats.alpha_measured == pytest.approx(1.0)
        # reference: per-user sorted timestamps, session cut at gap>1000
        ts_all = (vals & ((1 << 32) - 1)).astype(np.int64)
        for k, v in outs:
            for u in np.unique(k):
                got_ts = np.sort((v[k == u] & ((1 << 32) - 1)).astype(np.int64))
                ref_ts = np.sort(ts_all[users == u])
                np.testing.assert_array_equal(got_ts, ref_ts)
                got_sess = (v[k == u] >> 32)
                n_sessions = len(np.unique(got_sess))
                gaps = np.diff(ref_ts)
                assert n_sessions == 1 + int((gaps > 1000).sum())


class TestInvertedIndex:
    def test_index_complete_and_expanding(self, platform):
        keys, vals = generate_documents(100, 30, seed=4)
        eng = GeoMapReduce(platform, uniform_plan(platform), inverted_index())
        outs, stats = eng.run(_split_sources(keys, vals, platform.nS))
        assert stats.alpha_measured > 1.0  # full index expands the data
        total_postings = sum(len(k) for k, _ in outs)
        assert total_postings == len(vals)  # every (doc,pos,word) indexed


class TestSyntheticAlpha:
    @pytest.mark.parametrize("alpha", [0.5, 1.0, 2.0])
    def test_alpha_control(self, platform, alpha):
        keys = np.arange(4000, dtype=np.int64)
        vals = keys.copy()
        eng = GeoMapReduce(platform, uniform_plan(platform), synthetic_alpha_job(alpha))
        _, stats = eng.run(_split_sources(keys, vals, platform.nS))
        assert stats.alpha_measured == pytest.approx(alpha, rel=0.02)


class TestEmptyPartitions:
    """Empty mapper/reducer partitions must inherit the app's value dtype
    and trailing shape (regression: they were created as flat ``np.int64``,
    breaking float / vector-valued loads)."""

    @staticmethod
    def _vector_app() -> MRApp:
        def map_fn(keys, values):
            # genuinely vectorial: touches axis 1, so a mis-shaped empty
            # partition ((0,) instead of (0, 2)) would crash here
            return keys, values[:, ::-1] * np.float32(2.0)

        def reduce_fn(keys, values):
            return keys, values

        return MRApp(name="vec", map_fn=map_fn, reduce_fn=reduce_fn,
                     record_bytes=8, intermediate_record_bytes=8)

    def test_vector_float_values_with_empty_nodes(self):
        p = two_cluster_example()
        keys = np.arange(100, dtype=np.int64)
        vals = np.random.default_rng(0).normal(size=(100, 2)).astype(np.float32)
        # mapper 1 receives nothing, reducer 1 owns nothing
        plan = ExecutionPlan(x=np.array([[1.0, 0.0], [1.0, 0.0]]),
                             y=np.array([1.0, 0.0]))
        eng = GeoMapReduce(p, plan, self._vector_app(), n_buckets=64)
        outs, stats = eng.run([(keys[:50], vals[:50]), (keys[50:], vals[50:])])
        for k, v in outs:
            assert k.dtype == np.int64
            assert v.dtype == np.float32
            assert v.shape[1:] == (2,)
        # mixed (empty + non-empty) outputs concatenate cleanly
        merged = np.concatenate([v for _, v in outs])
        assert merged.shape == (100, 2)
        np.testing.assert_allclose(np.sort(merged, axis=0),
                                   np.sort(vals[:, ::-1] * 2.0, axis=0),
                                   rtol=1e-6)

    def test_empty_source_keeps_dtype(self):
        p = two_cluster_example()
        keys = np.arange(40, dtype=np.int64)
        vals = np.linspace(0.0, 1.0, 40, dtype=np.float64)
        empty = (keys[:0], vals[:0])
        eng = GeoMapReduce(p, uniform_plan(p), self._scalar_float_app())
        outs, _ = eng.run([(keys, vals), empty])
        for _, v in outs:
            assert v.dtype == np.float64

    @staticmethod
    def _scalar_float_app() -> MRApp:
        return MRApp(name="fid", map_fn=lambda k, v: (k, v),
                     reduce_fn=lambda k, v: (k, v),
                     record_bytes=8, intermediate_record_bytes=8)


class TestPlanEnforcement:
    def test_push_bytes_follow_plan(self, platform):
        keys = np.arange(80_000, dtype=np.int64)
        vals = keys.copy()
        plan = optimize_plan(platform, "e2e_multi", n_restarts=6, steps=250).plan
        eng = GeoMapReduce(platform, plan, synthetic_alpha_job(1.0))
        _, stats = eng.run(_split_sources(keys, vals, platform.nS))
        frac = stats.push_bytes / stats.push_bytes.sum(axis=1, keepdims=True)
        np.testing.assert_allclose(frac, plan.x, atol=2e-3)

    def test_optimized_beats_uniform_and_local(self, platform):
        """Fig 9 in miniature: measured-bytes makespan ordering on the
        emulated PlanetLab platform."""
        keys, vals = generate_documents(400, 60, seed=5)
        srcs = _split_sources(keys, vals, platform.nS)
        app = word_count()
        results = {}
        for name, plan in [
            ("uniform", uniform_plan(platform)),
            ("hadoop_local", local_push_plan(platform)),
            ("optimized", optimize_plan(platform, "e2e_multi",
                                        n_restarts=8, steps=300).plan),
        ]:
            _, stats = GeoMapReduce(platform, plan, app).run(srcs)
            results[name] = stats.makespan(platform)["makespan"]
        assert results["optimized"] < results["hadoop_local"]
        assert results["optimized"] < results["uniform"]
