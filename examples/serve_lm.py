"""Serve a small model with batched requests through the
continuous-batching engine.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch import serve as serve_launcher

serve_launcher.main([
    "--arch", "qwen3-1.7b",
    "--reduced",
    "--requests", "12",
    "--slots", "4",
    "--max-len", "128",
    "--max-new", "16",
])
