"""Online control plane: progress-aware re-planning over streaming arrivals
and drifting capacities.

The offline planner decides once, against a frozen view of the fabric — but
the world refuses to hold still.  This example puts the closed
plan→observe→re-plan loop (PR 3) on the spot with the two disturbances a
geo-distributed scheduler actually faces:

* a **capacity drift**: both backbone shuffle links into the fast reducer
  r0 degrade 250x at t=105s, mid-shuffle of the running job (a
  :class:`repro.core.platform.CapacityTrace` the planner does not know);
* a **streaming arrival**: a second job turns up at t=50s, mid-map, known
  to nobody at t=0 (except the clairvoyant frozen baseline, which still
  loses).

The frozen joint plan — offline-optimal, even told the arrival's release
time in advance — pushes its residual shuffle through the collapsed links
and crawls.  The ``reactive`` policy pauses the executor at each event,
snapshots every job's *residual* work, re-plans it against the capacities
then in force (``Substrate.at(t)``, warm-started from the incumbent plan),
and swaps the not-yet-committed chunks onto the healthy path.

Part 2 (PR 4) then shows where ``reactive`` itself turns myopic: each
job's residual is re-planned *solo*, so concurrent jobs spill onto the
same resources.  ``reactive_shared`` co-replans every live residual
jointly through shared-capacity pricing and charges each swap its replan
cost (``OnlineConfig.hysteresis``), beating both the frozen joint plan
and solo-residual re-planning with fewer accepted swaps than
hysteresis-free co-replanning.

    PYTHONPATH=src python examples/geo_online.py
"""
import dataclasses

import numpy as np

from repro.api import Arrival, GeoJob, GeoSchedule, OnlineConfig
from repro.core import (
    BARRIERS_GGL,
    CapacityTrace,
    SimConfig,
    Substrate,
    available_online_policies,
    simulate_schedule,
)

OPT = dict(n_restarts=8, steps=250)

substrate = Substrate(
    B_sm=np.full((2, 2), 200.0),
    B_mr=np.array([[500.0, 100.0],   # backbone links into r0 are the fast path
                   [500.0, 100.0]]),
    C_m=np.array([100.0, 100.0]),
    C_r=np.array([2000.0, 2000.0]),
    cluster_s=np.array([0, 1]),
    cluster_m=np.array([0, 1]),
    cluster_r=np.array([0, 1]),
    name="online_pair",
).with_traces({
    # ... until they collapse to 2 MB/s at t=105s, mid-shuffle
    "shuffle[m0->r0]": CapacityTrace.step(500.0, 2.0, 105.0),
    "shuffle[m1->r0]": CapacityTrace.step(500.0, 2.0, 105.0),
})
print(substrate.describe())
print("registered online policies:", ", ".join(available_online_policies()))

steady = GeoJob(substrate.view(np.array([8000.0, 8000.0]), 1.0, name="steady"))
late_view = substrate.view(np.array([4000.0, 4000.0]), 1.0, name="late")
cfg = SimConfig(barriers=BARRIERS_GGL)
t_arrival = 50.0

# ---------------------------------------------------------------------------
# the frozen baseline: everything planned jointly offline — it even knows the
# arrival's release time — but against the NOMINAL capacities
# ---------------------------------------------------------------------------
frozen = GeoSchedule([steady, GeoJob(late_view)]).plan(
    "joint", mode="e2e_multi", barriers=BARRIERS_GGL, **OPT
)
frozen_sim = simulate_schedule(
    [(steady.platform, frozen.planned.plans[0], cfg),
     (late_view, frozen.planned.plans[1],
      dataclasses.replace(cfg, start_time=t_arrival))],
    substrate=substrate,
)
print(f"\nfrozen joint plan (clairvoyant offline): "
      f"{frozen_sim.makespan:8.0f}s aggregate")

# ---------------------------------------------------------------------------
# the online loop: plan -> observe -> re-plan
# ---------------------------------------------------------------------------
sched = GeoSchedule([steady]).plan(
    "independent", mode="e2e_multi", barriers=BARRIERS_GGL, **OPT
)
print(f"\n{'policy':10s} {'online':>9s} {'vs frozen':>10s}  decisions")
reports = {}
for policy, extra in (("static", {}), ("reactive", {}),
                      ("horizon", {"replan_dt": 40.0})):
    arrival = Arrival(
        GeoJob(late_view).with_plan(frozen.planned.plans[1], BARRIERS_GGL),
        t_arrival,
    )
    report = sched.run_online(policy=policy, arrivals=[arrival], cfg=cfg,
                              **OPT, **extra)
    reports[policy] = report
    gain = 1 - report.makespan_online / frozen_sim.makespan
    print(f"{policy:10s} {report.makespan_online:8.0f}s {gain:9.0%}  "
          f"{len(report.swaps)} swaps / {len(report.decisions)} decisions")

reactive = reports["reactive"]
print("\nreactive decision timeline (modeled remaining seconds):")
print(reactive.timeline())
print(f"\nreactive re-planning beats the frozen joint plan by "
      f"{1 - reactive.makespan_online / frozen_sim.makespan:.0%} "
      f"({frozen_sim.makespan:.0f}s -> {reactive.makespan_online:.0f}s).")
print(reactive.summary())

# ---------------------------------------------------------------------------
# part 2: solo-residual re-planning is schedule-myopic — co-replan instead
# ---------------------------------------------------------------------------
# Asymmetric reducer access: the steady job's mappers (m0/m1) reach both
# reducers, the late job's mappers (m2/m3) can only shuffle into r1 — the
# late job is STUCK on r1, a fact only shared-capacity pricing can see.
# When the fast reducer r0 degrades mid-shuffle (300 -> 40 MB/s), solo
# replanning balances the steady job's residual against the raw capacities
# and spills onto r1, right on top of the stuck job.  The two later trace
# steps on dead push links change nothing real — they only bait
# hysteresis-free re-planning into epsilon swaps (thrash).
shared_sub = Substrate(
    B_sm=np.array([
        [200.0, 200.0, 1.0, 1.0],
        [200.0, 200.0, 1.0, 1.0],
        [1.0, 1.0, 200.0, 200.0],
        [1.0, 1.0, 200.0, 200.0],
    ]),
    B_mr=np.array([
        [200.0, 200.0],
        [200.0, 200.0],
        [1.0, 200.0],
        [1.0, 200.0],
    ]),
    C_m=np.array([100.0, 100.0, 100.0, 100.0]),
    C_r=np.array([300.0, 60.0]),
    cluster_s=np.array([0, 0, 1, 1]),
    cluster_m=np.array([0, 0, 1, 1]),
    cluster_r=np.array([0, 1]),
    name="online_shared",
).with_traces({
    "reduce[r0]": CapacityTrace.step(300.0, 40.0, 110.0),
    "push[s0->m2]": CapacityTrace.step(1.0, 0.9, 150.0),   # nuisance
    "push[s1->m2]": CapacityTrace.step(1.0, 0.9, 180.0),   # nuisance
})
print("\n--- part 2: shared-capacity co-replanning with hysteresis ---")
print(shared_sub.describe())

steady2 = GeoJob(shared_sub.view(np.array([8000.0, 8000.0, 0.0, 0.0]), 1.0,
                                 name="steady"))
stuck_view = shared_sub.view(np.array([0.0, 0.0, 6000.0, 6000.0]), 1.0,
                             name="late")

frozen2 = GeoSchedule([steady2, GeoJob(stuck_view)]).plan(
    "joint", mode="e2e_multi", barriers=BARRIERS_GGL, **OPT
)
frozen2_sim = simulate_schedule(
    [(steady2.platform, frozen2.planned.plans[0], cfg),
     (stuck_view, frozen2.planned.plans[1],
      dataclasses.replace(cfg, start_time=t_arrival))],
    substrate=shared_sub,
)
print(f"\nfrozen joint plan (clairvoyant offline): "
      f"{frozen2_sim.makespan:8.0f}s aggregate")

sched2 = GeoSchedule([steady2]).plan(
    "independent", mode="e2e_multi", barriers=BARRIERS_GGL, **OPT
)
print(f"\n{'variant':22s} {'online':>9s} {'vs frozen':>10s}  "
      "swaps/rejected/decisions")
reports2 = {}
for name, policy, online in (
    ("reactive (solo)", "reactive", None),
    # solver_cost_s pinned: the hysteresis narrative below is about the
    # gate, and the MEASURED charge (the default, a compile-excluded EMA
    # of observed solve time) depends on how fast this host solves
    ("reactive_shared", "reactive_shared",
     OnlineConfig(shared=True, hysteresis=1.0, solver_cost_s=1.0)),
    ("shared, no hysteresis", "reactive_shared",
     OnlineConfig(shared=True, hysteresis=0.0)),
    # warm-started incremental re-solves + the measured charge (PR 7)
    ("reactive_incremental", "reactive_incremental", None),
):
    arrival = Arrival(
        GeoJob(stuck_view).with_plan(frozen2.planned.plans[1], BARRIERS_GGL),
        t_arrival,
    )
    report = sched2.run_online(policy=policy, arrivals=[arrival], cfg=cfg,
                               online=online, **OPT)
    reports2[name] = report
    gain = 1 - report.makespan_online / frozen2_sim.makespan
    print(f"{name:22s} {report.makespan_online:8.0f}s {gain:9.0%}  "
          f"{len(report.swaps)}/{len(report.rejected)}"
          f"/{len(report.decisions)}")

shared = reports2["reactive_shared"]
solo = reports2["reactive (solo)"]
nohyst = reports2["shared, no hysteresis"]
print("\nreactive_shared decision timeline (modeled remaining seconds):")
print(shared.timeline())
print(f"\nco-replanning beats the frozen joint plan by "
      f"{1 - shared.makespan_online / frozen2_sim.makespan:.0%} and "
      f"solo-residual reactive by "
      f"{1 - shared.makespan_online / solo.makespan_online:.0%}, "
      f"accepting {len(shared.swaps)} swaps vs "
      f"{len(nohyst.swaps)} without hysteresis "
      f"({len(shared.rejected)} rejected, "
      f"{shared.charged_s:.0f}s charged).")
print(shared.summary())
