"""End-to-end training driver: train a ~20M-param Qwen3-family model for a
few hundred steps on CPU with geo-planned ingest, async checkpointing and
resume-after-kill.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

This is a thin veneer over ``repro.launch.train`` (the production
launcher); it also demonstrates the kill/resume cycle by checkpointing
every 50 steps — re-running the same command continues from the newest
committed checkpoint.
"""
import argparse

from repro.launch import train as train_launcher

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

train_launcher.main([
    "--arch", "qwen3-1.7b",
    "--reduced",
    "--steps", str(args.steps),
    "--batch", "8",
    "--seq", "128",
    "--lr", "1e-3",
    "--ckpt-dir", args.ckpt_dir,
    "--ckpt-every", "50",
    "--resume", "auto",
    "--geo-ingest",
    "--log-every", "10",
])
