"""Quickstart: the paper's whole loop through the job-level `GeoJob` API.

A job bundles the three stages the paper argues must be optimized
*together* rather than myopically:

1. **model** a distributed platform — bandwidths, compute rates, data at
   each source (here: 8 PlanetLab-derived data centers);
2. **plan** an execution plan with any registered planner mode
   (``repro.core.optimize.available_modes()`` lists them; new strategies
   plug in via ``register_planner`` without touching the solver) — here the
   paper's ``e2e_multi`` end-to-end multi-phase optimization against two
   baselines;
3. **execute** — here on the chunk-granular discrete-event executor via
   ``job.simulate()``; both the modeled and the executed numbers are priced
   by the same shared cost model, so they are directly comparable.  See
   ``examples/geo_wordcount.py`` for real map/reduce execution with
   measured byte matrices.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import GeoJob
from repro.core import (
    BARRIERS_GGL, local_push_plan, planetlab_platform, uniform_plan,
)
from repro.core.optimize import available_modes

# An 8-data-center, globally distributed platform with PlanetLab-measured
# bandwidth/compute heterogeneity; alpha=1 (e.g. a distributed sort).
platform = planetlab_platform(n_datacenters=8, alpha=1.0, seed=0)
print(platform.describe())
print("registered planner modes:", ", ".join(available_modes()))

setups = {
    "uniform": lambda j: j.with_plan(uniform_plan(platform), BARRIERS_GGL),
    "hadoop-locality": lambda j: j.with_plan(local_push_plan(platform), BARRIERS_GGL),
    "e2e-multi (paper)": lambda j: j.plan("e2e_multi", barriers=BARRIERS_GGL),
}

results = {}
print(f"\n{'plan':22s} {'model makespan':>15s} {'executed':>10s}  phases")
for name, setup in setups.items():
    job = setup(GeoJob(platform))
    results[name] = job.planned
    executed = job.simulate().makespan
    bd = results[name].breakdown
    phases = " ".join(f"{k}={bd[k]:.0f}s" for k in ("push", "map", "shuffle", "reduce"))
    print(f"{name:22s} {results[name].makespan:13.0f}s {executed:9.0f}s  {phases}")

best = results["e2e-multi (paper)"]
uni = results["uniform"]
print(f"\nend-to-end multi-phase plan reduces makespan by "
      f"{1 - best.makespan / uni.makespan:.0%} vs uniform "
      f"(paper reports 82-87% on its platform).")
print("optimized push matrix x (rows=sources, cols=mappers):")
print(np.round(best.plan.x, 2))
print("optimized shuffle fractions y:", np.round(best.plan.y, 3))
