"""Quickstart: model a distributed platform, optimize an execution plan,
and compare it against the baselines — the paper's core loop in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    BARRIERS_GGL, SimConfig, makespan, optimize_plan, phase_breakdown,
    planetlab_platform, simulate, uniform_plan, local_push_plan,
)

# An 8-data-center, globally distributed platform with PlanetLab-measured
# bandwidth/compute heterogeneity; alpha=1 (e.g. a distributed sort).
platform = planetlab_platform(n_datacenters=8, alpha=1.0, seed=0)
print(platform.describe())

plans = {
    "uniform": uniform_plan(platform),
    "hadoop-locality": local_push_plan(platform),
    "e2e-multi (paper)": optimize_plan(platform, "e2e_multi").plan,
}

print(f"\n{'plan':22s} {'model makespan':>15s} {'executed':>10s}  phases")
for name, plan in plans.items():
    model_t = makespan(platform, plan, BARRIERS_GGL)
    executed = simulate(platform, plan, SimConfig(barriers=BARRIERS_GGL)).makespan
    bd = phase_breakdown(platform, plan, BARRIERS_GGL)
    phases = " ".join(f"{k}={bd[k]:.0f}s" for k in ("push", "map", "shuffle", "reduce"))
    print(f"{name:22s} {model_t:13.0f}s {executed:9.0f}s  {phases}")

best = optimize_plan(platform, "e2e_multi")
uni = makespan(platform, plans["uniform"], BARRIERS_GGL)
print(f"\nend-to-end multi-phase plan reduces makespan by "
      f"{1 - best.makespan / uni:.0%} vs uniform "
      f"(paper reports 82-87% on its platform).")
print("optimized push matrix x (rows=sources, cols=mappers):")
print(np.round(best.plan.x, 2))
print("optimized shuffle fractions y:", np.round(best.plan.y, 3))
