"""Multi-job scheduling: two concurrent jobs on one shared substrate.

The paper's core claim — end-to-end optimization beats myopic, per-phase
control — extends across *jobs* once the platform is shared: planning each
job as if it were alone ("independent", the per-job-myopic baseline) can
pile every job onto the same fast links and nodes, while planning them
together ("joint") routes around each other's demand.

The scenario: a two-mapper substrate where

* job A ("pinned") can only reach mapper 0 quickly — its source's link to
  mapper 1 is dead slow (1 MB/s vs 10 GB/s);
* job B ("flexible") reaches both mappers at full speed, so its *solo*
  optimum splits evenly across them — straight onto A's only mapper.

Planned independently, both jobs contend for mapper 0 and the schedule
drags; planned jointly (or greedily in sequence), job B cedes mapper 0 to
the job that has no alternative.  Every policy is priced by the same
shared-capacity float64 cost model the single-job path uses, and then
actually executed — concurrently, with real contention — on the
chunk-granular discrete-event executor.

    PYTHONPATH=src python examples/geo_multijob.py
"""
import numpy as np

from repro.api import GeoJob, GeoSchedule
from repro.core import BARRIERS_GGL, Substrate
from repro.core.optimize import available_policies

substrate = Substrate(
    B_sm=np.array([[10_000.0, 1.0],       # source 0: mapper 1 unreachable
                   [10_000.0, 10_000.0]]),  # source 1: anywhere
    B_mr=np.full((2, 2), 10_000.0),
    C_m=np.array([50.0, 50.0]),
    C_r=np.array([10_000.0, 10_000.0]),
    cluster_s=np.array([0, 1]),
    cluster_m=np.array([0, 1]),
    cluster_r=np.array([0, 1]),
    name="shared_pair",
)
print(substrate.describe())
print("registered schedule policies:", ", ".join(available_policies()))

# two 40 GB jobs: A's data sits at source 0, B's at source 1 — same
# substrate entries, different slices (Substrate.view shares the arrays)
job_a = GeoJob(substrate.view(np.array([40_000.0, 0.0]), 1.0, name="pinned"))
job_b = GeoJob(substrate.view(np.array([0.0, 40_000.0]), 1.0, name="flexible"))

print(f"\n{'policy':13s} {'modeled':>9s} {'executed':>9s}  "
      f"B's push split (m0, m1)")
reports = {}
for policy in ("independent", "sequential", "joint"):
    report = (
        GeoSchedule([job_a, job_b])
        .plan(policy=policy, mode="e2e_multi", barriers=BARRIERS_GGL,
              n_restarts=8, steps=250)
        .simulate()
    )
    reports[policy] = report
    m0, m1 = report.plans[1].x[1]
    print(f"{policy:13s} {report.makespan_modeled:8.0f}s "
          f"{report.makespan_sim:8.0f}s  ({m0:.2f}, {m1:.2f})")

indep, joint = reports["independent"], reports["joint"]
print(f"\njoint planning reduces the executed aggregate makespan by "
      f"{1 - joint.makespan_sim / indep.makespan_sim:.0%} vs per-job-myopic.")
print("hottest contended resources under the independent plans:")
util = indep.utilization()
for name in sorted(indep.contended(), key=lambda n: -util[n])[:3]:
    print(f"  {name}: {util[name]:.0%} busy over the schedule")
print("\n" + joint.summary())
