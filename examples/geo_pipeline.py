"""Multi-stage pipelines: end-to-end cross-stage planning vs stagewise.

Real geo-analytics workloads are chains of MapReduce stages — one stage's
reduce output is the next stage's source data.  That extends the paper's
core argument (end-to-end beats myopic, per-phase control) across a new
axis: *where a stage's reducers sit decides where the next stage's data
starts from*.

The scenario: two sites, and the twist is in the *outgoing* links.

* node 0 hosts the fast reducer (300 MB/s vs node 1's 60 MB/s), but its
  outgoing push links crawl at 4 MB/s;
* node 1's reducer is slow, but its outgoing links run at wire speed.

A 3-stage chain (6 GB ingest -> transform -> aggregate) planned
``stagewise`` places each stage's reduce output on the fast reducer —
locally optimal, and it strands the next stage's entire input behind the
4 MB/s links.  ``end_to_end`` optimizes all stages' push and shuffle
fractions in one solve, with gradients flowing through the inter-stage
coupling (downstream D is a function of upstream y): it concedes reduce
speed on the non-final stages to keep their output on the well-connected
node, and only the sink stage uses the fast reducer.

Both plans then actually run on the chunk-granular executor, where a
downstream stage's push chunks at source node s release only when the
upstream reduce output destined for s lands.

    PYTHONPATH=src python examples/geo_pipeline.py
"""
import numpy as np

from repro.api import GeoJob, GeoPipeline
from repro.core import BARRIERS_GGL, Substrate
from repro.core.optimize import available_pipeline_modes

OPT = dict(n_restarts=8, steps=250)

substrate = Substrate(
    B_sm=np.array([[4.0, 4.0],        # node 0: fast reducer, dead-slow exit
                   [200.0, 200.0]]),  # node 1: slow reducer, fast exit
    B_mr=np.full((2, 2), 200.0),
    C_m=np.array([100.0, 100.0]),
    C_r=np.array([300.0, 60.0]),
    cluster_s=np.array([0, 1]),
    cluster_m=np.array([0, 1]),
    cluster_r=np.array([0, 1]),
    name="pipeline_pair",
)
print(substrate.describe())
print("registered pipeline planners:",
      ", ".join(available_pipeline_modes()))


def stages():
    """6 GB at the well-connected node; downstream stages' D is derived
    from the upstream plans (their views start empty)."""
    return [
        GeoJob(substrate.view(np.array([0.0, 6000.0]), 1.0, name="ingest")),
        GeoJob(substrate.view(np.zeros(2), 1.0, name="transform")),
        GeoJob(substrate.view(np.zeros(2), 0.5, name="aggregate")),
    ]


print(f"\n{'mode':11s} {'modeled':>9s} {'simulated':>9s}  "
      "reduce split per stage (r0, r1)")
reports = {}
for mode in ("stagewise", "end_to_end"):
    report = (
        GeoPipeline(stages(), name=f"chain_{mode}")
        .plan(mode, stage_mode="e2e_multi", barriers=BARRIERS_GGL, **OPT)
        .simulate()
    )
    reports[mode] = report
    splits = "  ".join(
        f"({p.y[0]:.2f}, {p.y[1]:.2f})" for p in report.plans
    )
    print(f"{mode:11s} {report.makespan_modeled:8.0f}s "
          f"{report.makespan_sim:8.0f}s  {splits}")

sw, e2e = reports["stagewise"], reports["end_to_end"]
print(f"\nstagewise strands stage k+1's input behind node 0's 4 MB/s "
      f"links;\nend-to-end planning cuts the simulated pipeline makespan "
      f"by {1 - e2e.makespan_sim / sw.makespan_sim:.0%}.")
print("\nper-stage start/finish (end_to_end, modeled):")
for k, (t0, t1) in enumerate(zip(e2e.result.starts, e2e.result.finishes)):
    print(f"  stage {k}: {t0:7.1f}s -> {t1:7.1f}s")
print("\n" + e2e.summary())

assert e2e.makespan_modeled <= sw.makespan_modeled + 1e-9, \
    "end_to_end must never be modeled-worse (stagewise competes)"
assert 1 - e2e.makespan_sim / sw.makespan_sim >= 0.20, \
    "expected a >=20% simulated win on this scenario"
