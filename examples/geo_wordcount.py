"""Geo-distributed Word Count through the `GeoJob` facade.

Runs the paper's Word Count application (in-mapper combining, Pallas
segment-sum reduce) over an 8-data-center platform under three execution
plans — the Fig-9 experiment in miniature.  ``calibrate`` probe-measures
the app's real expansion factor α and input volumes, ``plan`` optimizes
against them, and ``execute`` prices the *measured* byte movement through
the same cost model the planner used, so every report shows modeled vs
measured makespan side by side.

    PYTHONPATH=src python examples/geo_wordcount.py
"""
from repro.api import GeoJob, split_sources
from repro.core import BARRIERS_GGL, local_push_plan, planetlab_platform, uniform_plan
from repro.mapreduce.apps import generate_documents, word_count

keys, vals = generate_documents(n_docs=800, words_per_doc=60, seed=0)
base = planetlab_platform(8, alpha=1.0, seed=0)
sources = split_sources(keys, vals, base.nS)

# probe-measure the app's real expansion factor, then plan with it
job = GeoJob(base, word_count()).calibrate(sources)
print(f"measured alpha = {job.platform.alpha:.3f} "
      f"(paper's WordCount: 0.09 — heavy aggregation)")

setups = {
    "uniform": lambda: job.with_plan(uniform_plan(job.platform), BARRIERS_GGL),
    "hadoop-locality": lambda: job.with_plan(local_push_plan(job.platform), BARRIERS_GGL),
    "optimized": lambda: job.plan("e2e_multi", barriers=BARRIERS_GGL),
}
reports = {}
for name, setup in setups.items():
    setup()
    reports[name] = job.execute(sources)
    n_words = sum(len(k) for k, _ in reports[name].outputs)
    print(f"{name:16s} {reports[name].summary()}  ({n_words} unique words)")

red = 1 - (reports["optimized"].makespan_measured
           / reports["hadoop-locality"].makespan_measured)
print(f"\noptimized plan beats the Hadoop-locality baseline by {red:.0%} "
      f"(paper: 36% for WordCount)")
