"""Geo-distributed Word Count on the plan-driven MapReduce engine.

Runs the paper's Word Count application (in-mapper combining, Pallas
segment-sum reduce) over an 8-data-center platform under three execution
plans, pricing the *measured* byte movement through the platform model —
the Fig-9 experiment in miniature.

    PYTHONPATH=src python examples/geo_wordcount.py
"""
import numpy as np

from repro.core import (
    BARRIERS_GGL, local_push_plan, optimize_plan, planetlab_platform,
    uniform_plan,
)
from repro.mapreduce.apps import generate_documents, word_count
from repro.mapreduce.engine import GeoMapReduce

keys, vals = generate_documents(n_docs=800, words_per_doc=60, seed=0)
probe_platform = planetlab_platform(8, alpha=1.0, seed=0)
sources = list(zip(np.array_split(keys, probe_platform.nS),
                   np.array_split(vals, probe_platform.nS)))
app = word_count()

# measure the app's real expansion factor with a probe, then plan with it
_, probe = GeoMapReduce(probe_platform, uniform_plan(probe_platform), app).run(sources)
print(f"measured alpha = {probe.alpha_measured:.3f} "
      f"(paper's WordCount: 0.09 — heavy aggregation)")
platform = planetlab_platform(8, alpha=max(probe.alpha_measured, 0.01), seed=0)

plans = {
    "uniform": uniform_plan(platform),
    "hadoop-locality": local_push_plan(platform),
    "optimized": optimize_plan(platform, "e2e_multi", barriers=BARRIERS_GGL).plan,
}
results = {}
for name, plan in plans.items():
    outs, stats = GeoMapReduce(platform, plan, app).run(sources)
    results[name] = stats.makespan(platform, BARRIERS_GGL)
    n_words = sum(len(k) for k, _ in outs)
    print(f"{name:16s} makespan={results[name]['makespan']:8.1f}s  "
          f"push={results[name]['push']:7.1f}s "
          f"shuffle={results[name]['shuffle']:6.1f}s  ({n_words} unique words)")

red = 1 - results["optimized"]["makespan"] / results["hadoop-locality"]["makespan"]
print(f"\noptimized plan beats the Hadoop-locality baseline by {red:.0%} "
      f"(paper: 36% for WordCount)")
