"""Planner-as-a-service: batched solves, the shape-keyed executable cache,
and incremental warm-start replans.

A control plane that plans for a fleet doesn't solve one problem and exit —
it fields a *stream* of requests: new jobs arriving (same substrate, new
volumes), periodic residual re-plans, the occasional novel topology.  Three
properties make that cheap (PR 7):

* **the executable cache** — jitted solver kernels are keyed by problem
  shape + static config, process-wide.  The first request of a shape pays
  the XLA compile; every later request of that shape (any volumes, any
  seed, any :class:`~repro.api.GeoSchedule`) reuses the executable.
* **batched solves** — N concurrent same-shape requests are vmapped into
  ONE dispatch, so the per-call Python/dispatch overhead is paid once.
* **incremental replans** — when an incumbent plan exists, a short
  low-temperature polish from the incumbent's logits replaces the full
  annealed re-solve; the incumbent competes in the final f64 pricing, so
  the result is never modeled worse than keeping it.

    PYTHONPATH=src python examples/geo_planner_service.py
"""
import time

import numpy as np

from repro.core import SolverService, solver_cache_stats
from repro.core.makespan import BARRIERS_GGL
from repro.core.platform import planetlab_platform

OPT = dict(n_restarts=8, steps=150)

svc = SolverService(mode="e2e_multi", barriers=BARRIERS_GGL, **OPT)


def timed(label, fn):
    before = solver_cache_stats()
    t0 = time.perf_counter()
    out = fn()
    dt = time.perf_counter() - t0
    after = solver_cache_stats()
    print(f"{label:42s} {dt * 1e3:9.1f} ms   "
          f"+{after['compiles'] - before['compiles']} compiles, "
          f"+{after['hits'] - before['hits']} cache hits")
    return out, dt


# ---------------------------------------------------------------------------
# 1. cold vs warm: the first request of a shape pays the compile
# ---------------------------------------------------------------------------
print("--- request stream against one problem shape (8-node planetlab) ---")
cold_res, cold = timed(
    "cold  (first request: XLA compile)",
    lambda: svc.plan(planetlab_platform(8, alpha=1.0, seed=0), seed=0),
)
_, warm = timed(
    "warm  (new volumes, same shape)",
    lambda: svc.plan(planetlab_platform(8, alpha=1.3, seed=1), seed=1),
)
print(f"{'':42s} -> warm request is {cold / warm:.0f}x faster\n")

# ---------------------------------------------------------------------------
# 2. batching: 8 concurrent requests, one vmapped dispatch
# ---------------------------------------------------------------------------
fleet = [planetlab_platform(8, alpha=a, seed=s)
         for s, a in enumerate((0.5, 0.8, 1.0, 1.2, 1.5, 1.8, 2.0, 2.5))]
seeds = list(range(8))
svc.plan_many(fleet, seeds=seeds)          # compile the batch-of-8 executable
batch, t_batch = timed(
    f"batch ({len(fleet)} requests, one dispatch)",
    lambda: svc.plan_many(fleet, seeds=seeds),
)
_, t_seq = timed(
    f"sequential ({len(fleet)} warm requests)",
    lambda: [svc.plan(p, seed=s) for p, s in zip(fleet, seeds)],
)
print(f"{'':42s} -> {len(fleet) / t_batch:.0f} plans/s batched "
      f"vs {len(fleet) / t_seq:.0f} plans/s sequential\n")

# ---------------------------------------------------------------------------
# 3. incremental replans: polish the incumbent instead of re-solving
# ---------------------------------------------------------------------------
print("--- mid-flight residual replans for the fleet ---")
incumbents = [r.plan for r in batch]
# compile both replan executables up front — we're comparing solve time
svc.replan_many(fleet, incumbents, seeds=seeds)
svc.replan_many(fleet, incumbents, seeds=seeds, incremental=True)
full, t_full = timed(
    "full re-solve (fresh anneal)",
    lambda: svc.replan_many(fleet, incumbents, seeds=seeds),
)
inc, t_inc = timed(
    "incremental (warm-start polish)",
    lambda: svc.replan_many(fleet, incumbents, seeds=seeds, incremental=True),
)
worse = sum(i.makespan > b.makespan + 1e-9 for i, b in zip(inc, batch))
print(f"{'':42s} -> {t_full / t_inc:.1f}x faster, "
      f"{worse}/{len(fleet)} modeled worse than the incumbent "
      "(never-worse by construction)\n")

spans = np.array([r.makespan for r in inc])
print(f"fleet replan makespans: {np.min(spans):.0f}..{np.max(spans):.0f}s "
      f"(median {np.median(spans):.0f}s)")
print(f"cache counters: {solver_cache_stats()}")
print("online loops get all of this via policy='reactive_incremental' "
      "(shared co-replanning, hysteresis gated by MEASURED solve time).")
