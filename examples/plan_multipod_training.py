"""Apply the paper's planner to multi-pod LM training decisions:

1. cross-pod gradient-reduction ownership under heterogeneous DCN,
2. MoE dispatch capacity planning under heterogeneous expert shards,
3. geo-planned corpus ingest vs myopic nearest-source pulls.

    PYTHONPATH=src python examples/plan_multipod_training.py
"""
import numpy as np

from repro.api import GeoJob
from repro.core.collective_plan import plan_cross_pod_reduction
from repro.core.moe_plan import plan_moe_dispatch
from repro.core.platform import tpu_pod_platform
from repro.configs import get_config

# --- 1. gradient reduction: pod 2's DCN is degraded to 25% --------------------
cfg = get_config("llama4-scout-17b-a16e")
grad_mb = cfg.n_params() * 4 / 1e6 / 256  # f32 grads, per-chip shard
rp = plan_cross_pod_reduction(
    grad_mb=grad_mb,
    pod_dcn_bw_mbps=[6400, 6400, 1600, 6400],
    n_elements=cfg.n_params() // 256,
)
print("[collective] planned pod ownership:", np.round(rp.fractions, 3))
print(f"[collective] modeled reduction time {rp.est_time_s*1e3:.1f} ms "
      f"vs uniform {rp.uniform_time_s*1e3:.1f} ms "
      f"({rp.speedup_vs_uniform:.2f}x)")

# --- 2. MoE dispatch: one expert pod is throttled ------------------------------
mp = plan_moe_dispatch(
    tokens_mb_per_shard=64.0,
    n_token_shards=8,
    group_pod=[0, 0, 0, 0, 1, 1, 1, 1],
    shard_pod=[0, 0, 0, 0, 1, 1, 1, 1],
    top_k=1,
    expert_flops_rate_mbps=[25000] * 4 + [10000] * 4,
)
print("\n[moe] planned group fractions:", np.round(mp.group_fractions, 3))
print(f"[moe] dispatch+compute {mp.est_time_s*1e3:.1f} ms vs uniform "
      f"{mp.uniform_time_s*1e3:.1f} ms ({mp.speedup_vs_uniform:.2f}x)")
print("[moe] router bias to load at init:", np.round(mp.router_bias, 2))

# --- 3. corpus ingest ----------------------------------------------------------
platform = tpu_pod_platform(n_pods=4, hosts_per_pod=4, compute_jitter=0.4, seed=1)
e2e = GeoJob(platform).plan("e2e_multi", n_restarts=8, steps=300).planned
myo = GeoJob(platform).plan("myopic_push", n_restarts=8, steps=300).planned
print(f"\n[ingest] e2e-planned makespan {e2e.makespan:.1f}s "
      f"vs myopic push {myo.makespan:.1f}s "
      f"({1 - e2e.makespan/myo.makespan:.0%} faster)")
